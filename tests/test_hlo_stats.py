"""Unit tests for the HLO-parsing roofline machinery (trip-count multipliers,
collective byte accounting, dot FLOP counting)."""
import pytest

from repro.launch import hlo_stats as H

SYNTH = """HloModule test

%body.1 (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %w = f32[16,32]{1,0} parameter(1)
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %dot.1 = f32[8,32]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[8,64]{1,0} all-gather(%x), replica_groups={}, dimensions={1}
  ROOT %t = (s32[], f32[8,16]) tuple(%p)
}

%cond.1 (p: (s32[], f32[8,16])) -> pred[] {
  ROOT %lt = pred[] constant(true)
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16]{1,0} parameter(0)
  %b = f32[16,8]{1,0} parameter(1)
  %dot.0 = f32[8,8]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%a), to_apply=%add
  %while.1 = (s32[], f32[8,16]) while(%t0), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%while.1), index=1
}
"""


def test_collective_trip_count_multiplier():
    stats = H.collective_stats(SYNTH)
    # all-reduce in entry: 8*16*4 = 512 B; all-gather in body x5 trips:
    # 8*64*4 * 5 = 10240 B
    assert stats.bytes_by_kind["all-reduce"] == 512
    assert stats.bytes_by_kind["all-gather"] == 8 * 64 * 4 * 5
    assert stats.count_by_kind["all-gather"] == 5


def test_dot_flops_trip_count():
    s = H.hlo_compute_stats(SYNTH)
    # entry dot: 2*8*8*16 = 2048; body dot x5: 2*8*32*16*5 = 40960
    assert s["dot_flops"] == 2048 + 40960


def test_async_collectives_counted_once():
    text = """HloModule m
ENTRY %main (a: f32[4,4]) -> f32[4,4] {
  %a = f32[4,4]{1,0} parameter(0)
  %ag-s = f32[4,8]{1,0} all-gather-start(%a), dimensions={1}
  %ag-d = f32[4,8]{1,0} all-gather-done(%ag-s)
  ROOT %r = f32[4,4]{1,0} copy(%a)
}
"""
    stats = H.collective_stats(text)
    assert stats.count_by_kind.get("all-gather", 0) == 1
    assert stats.bytes_by_kind["all-gather"] == 4 * 8 * 4


def test_decode_per_token_stats_divides_by_batch():
    # SYNTH totals: dot flops 2048 + 40960; dot bytes 1280 + 3584*5 = 19200;
    # collective bytes 512 + 10240 = 10752.  A decode step advances every
    # batch row by one token, so per-token = total / batch.
    pt = H.decode_per_token_stats(SYNTH, 4)
    assert pt["dot_flops_per_token"] == pytest.approx((2048 + 40960) / 4)
    assert pt["dot_bytes_per_token"] == pytest.approx(19200 / 4)
    assert pt["collective_bytes_per_token"] == pytest.approx(10752 / 4)


def test_decode_per_token_stats_batch_one_is_totals():
    pt = H.decode_per_token_stats(SYNTH, 1)
    s = H.hlo_compute_stats(SYNTH)
    assert pt["dot_flops_per_token"] == s["dot_flops"]
    assert pt["dot_bytes_per_token"] == s["dot_bytes"]


def test_decode_per_token_stats_rejects_bad_batch():
    with pytest.raises(ValueError, match="batch must be >= 1"):
        H.decode_per_token_stats(SYNTH, 0)


def test_roofline_terms_dominance():
    t = H.roofline_terms(
        flops=197e12, bytes_accessed=819e9, collective_bytes=100e9, chips=1
    )
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(2.0)
    assert t["dominant"] == "collective"


def test_shape_bytes_dtype_table():
    assert H._shape_bytes("bf16[2,3]") == 12
    assert H._shape_bytes("f32[10] s8[4]") == 44
    assert H._shape_bytes("pred[8]") == 8
