"""Checkpoint round-trips for chain model blocks.

``save_pytree``/``load_pytree`` carry three kinds of chain payloads:
raw f32 parameter pytrees, bf16-cast leaves, and int8-codec blobs
({"q", "scales", "d"}).  Serving restores through ``load_model_payload``,
which must hand back exactly what the trainer committed — dtype and bits.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import (
    is_quantized_blob,
    load_model_payload,
    load_pytree,
    save_pytree,
)
from repro.configs import registry
from repro.kernels.ops import Int8UpdateCodec
from repro.models import init_model


@pytest.fixture(scope="module")
def cfg():
    return registry.get_config(
        "olmo-1b", d_model=32, num_units=2, num_heads=2, num_kv_heads=2,
        d_ff=64, vocab_size=128,
    )


@pytest.fixture(scope="module")
def params(cfg):
    return init_model(jax.random.PRNGKey(0), cfg)


def assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert np.asarray(x).dtype == np.asarray(y).dtype
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_f32_params_roundtrip_structure_rebuild(params, tmp_path):
    p = str(tmp_path / "m.msgpack")
    save_pytree(p, params)
    got = load_pytree(p)
    assert jax.tree.structure(got) == jax.tree.structure(params)
    assert_trees_equal(got, params)


def test_f32_params_roundtrip_like(params, tmp_path):
    p = str(tmp_path / "m.msgpack")
    save_pytree(p, params)
    got = load_pytree(p, like=params)
    assert_trees_equal(got, params)


def test_bf16_leaves_roundtrip(params, tmp_path):
    half = jax.tree.map(lambda x: jnp.asarray(x, jnp.bfloat16), params)
    p = str(tmp_path / "bf16.msgpack")
    save_pytree(p, half)
    got = load_pytree(p)
    for x, y in zip(jax.tree.leaves(got), jax.tree.leaves(half)):
        assert x.dtype == jnp.bfloat16
        np.testing.assert_array_equal(
            np.asarray(x, np.float32), np.asarray(y, np.float32))


def test_int8_blob_roundtrip_preserves_dtypes(params, tmp_path):
    codec = Int8UpdateCodec(params)
    blob = codec.encode(jax.tree.map(lambda x: x * 0.5, params))
    assert is_quantized_blob(blob)
    p = str(tmp_path / "blob.msgpack")
    save_pytree(p, blob)
    got = load_pytree(p)
    assert is_quantized_blob(got)
    assert np.asarray(got["q"]).dtype == np.int8
    np.testing.assert_array_equal(np.asarray(got["q"]), np.asarray(blob["q"]))
    np.testing.assert_array_equal(
        np.asarray(got["scales"]), np.asarray(blob["scales"]))
    assert int(got["d"]) == int(blob["d"])


def test_tiered_layout_roundtrip(tmp_path):
    """Nested dict/tuple/list/None skeleton — the tiered chain record
    shapes (committee snapshots, per-tier aggregates) survive rebuild."""
    payload = {
        "tiers": (
            {"members": np.arange(5, dtype=np.int32),
             "scores": np.linspace(0, 1, 6, dtype=np.float32).reshape(2, 3)},
            {"members": np.arange(3, dtype=np.int32),
             "scores": None},
        ),
        "meta": [np.asarray(7, np.int64), None],
        "accept": np.asarray([True, False, True]),
    }
    p = str(tmp_path / "tier.msgpack")
    save_pytree(p, payload)
    got = load_pytree(p)
    assert isinstance(got["tiers"], tuple) and len(got["tiers"]) == 2
    assert got["tiers"][1]["scores"] is None
    assert isinstance(got["meta"], list) and got["meta"][1] is None
    np.testing.assert_array_equal(
        np.asarray(got["tiers"][0]["scores"]), payload["tiers"][0]["scores"])
    np.testing.assert_array_equal(
        np.asarray(got["accept"]), payload["accept"])
    assert int(got["meta"][0]) == 7


def test_load_model_payload_raw(params, tmp_path):
    p = str(tmp_path / "raw.msgpack")
    save_pytree(p, params)
    got = load_model_payload(p)
    assert_trees_equal(got, params)


def test_load_model_payload_blob_decodes(params, tmp_path):
    codec = Int8UpdateCodec(params)
    update = jax.tree.map(lambda x: x + 0.25, params)
    blob = codec.encode(update)
    p = str(tmp_path / "blob.msgpack")
    save_pytree(p, blob)
    got = load_model_payload(p, codec=codec)
    # decoded-from-disk must be bit-identical to decoded-from-memory
    assert_trees_equal(got, codec.decode(blob))
    assert jax.tree.structure(got) == jax.tree.structure(params)


def test_load_model_payload_blob_requires_codec(params, tmp_path):
    blob = Int8UpdateCodec(params).encode(params)
    p = str(tmp_path / "blob.msgpack")
    save_pytree(p, blob)
    with pytest.raises(ValueError, match="int8 chain blob"):
        load_model_payload(p)


def test_is_quantized_blob_rejects_lookalikes(params):
    assert not is_quantized_blob(params)
    assert not is_quantized_blob({"q": 1, "scales": 2})
    # a params tree whose top-level keys collide but whose "d" is a subtree
    nested = {"q": np.zeros(2), "scales": np.zeros(2), "d": {"w": np.zeros(2)}}
    assert not is_quantized_blob(nested)
