"""End-to-end behaviour tests for the whole system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.consensus import consensus_cost


def test_lm_driver_learns():
    """The production train_step drives loss toward the Markov-chain entropy
    floor (ln V ~ 9 -> well below unigram)."""
    import argparse

    from repro.launch.train import run_lm

    args = argparse.Namespace(
        steps=100, batch=16, seq=64, lr=5e-3, mode="standard", cohorts=2,
        committee=2, small=True, use_all_devices=False, ckpt="",
        log_every=100, vocab=512,
    )
    final = run_lm(args)
    assert final < 5.0  # started at ln(512) ~ 6.24


def test_bflc_mode_lm_driver_runs():
    import argparse

    from repro.launch.train import run_lm

    args = argparse.Namespace(
        steps=10, batch=8, seq=32, lr=1e-3, mode="bflc", cohorts=4,
        committee=4, small=True, use_all_devices=False, ckpt="",
        log_every=100, vocab=512,
    )
    final = run_lm(args)
    assert np.isfinite(final)


def test_fl_driver_end_to_end():
    import argparse

    from repro.launch.train import run_fl

    args = argparse.Namespace(
        clients=20, rounds=2, active=0.5, k_updates=3, local_steps=3,
        malicious=0.0, seed=0, log_every=2,
    )
    acc = run_fl(args)
    assert 0.0 <= acc <= 1.0


def test_consensus_cheaper_at_paper_scale():
    # paper §V.A: 900 devices, 10% active, 40% committee
    active = 90
    q = int(active * 0.4)
    p = active - q
    ccm, broadcast = consensus_cost(p, q)
    assert ccm * 4 < broadcast


def test_chain_storage_quantized_updates():
    """§IV.D storage optimization: int8 update blocks via the Pallas codec."""
    from repro.core.blockchain import Chain
    from repro.kernels.ops import dequantize_pytree, quantize_pytree

    update = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 64))}
    blob, unravel = quantize_pytree(update)
    chain = Chain(1)
    chain.append_model({"w": jnp.zeros((64, 64))}, 0)
    chain.append_update(blob, uploader=0, score=0.9)
    chain.append_model({"w": jnp.ones((64, 64))}, 1)
    assert chain.verify()
    restored = dequantize_pytree(chain.blocks[1].payload, unravel)
    err = float(jnp.abs(restored["w"] - update["w"]).max())
    assert err < 0.1
    # int8 payload is ~4x smaller than f32
    q_bytes = chain.blocks[1].payload["q"].nbytes
    assert q_bytes * 3 < update["w"].nbytes
