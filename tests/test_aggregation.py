"""Aggregation strategies + robustness properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.aggregation import (
    aggregate_pytrees,
    apply_update,
    cwmed,
    fedavg,
    flatten_updates,
    trimmed_mean,
)


def rand_updates(k, seed=0):
    key = jax.random.PRNGKey(seed)
    return [
        {"a": jax.random.normal(jax.random.fold_in(key, i), (8, 8)),
         "b": jax.random.normal(jax.random.fold_in(key, 100 + i), (5,))}
        for i in range(k)
    ]


def test_fedavg_weighted_mean():
    ups = rand_updates(4)
    stack, unravel = flatten_updates(ups)
    w = jnp.array([1.0, 1.0, 2.0, 0.0])
    out = fedavg(stack, w)
    expect = (stack[0] + stack[1] + 2 * stack[2]) / 4.0
    np.testing.assert_allclose(out, expect, atol=1e-6)


def test_cwmed_matches_numpy():
    ups = rand_updates(5)
    stack, _ = flatten_updates(ups)
    np.testing.assert_allclose(
        cwmed(stack), np.median(np.asarray(stack), axis=0), atol=1e-6
    )


def test_cwmed_robust_to_outlier():
    """One poisoned update cannot push the median outside the honest range."""
    ups = rand_updates(5)
    stack, _ = flatten_updates(ups)
    poisoned = stack.at[0].set(1e6)
    med = cwmed(poisoned)
    honest_lo = np.asarray(stack[1:]).min(axis=0)
    honest_hi = np.asarray(stack[1:]).max(axis=0)
    assert np.all(med >= honest_lo - 1e-6) and np.all(med <= honest_hi + 1e-6)


def test_fedavg_not_robust():
    ups = rand_updates(5)
    stack, _ = flatten_updates(ups)
    poisoned = stack.at[0].set(1e6)
    out = fedavg(poisoned)
    assert np.abs(np.asarray(out)).max() > 1e4  # poisoned mean explodes


def test_trimmed_mean():
    stack = jnp.array([[1.0, 5.0], [2.0, 6.0], [3.0, 7.0], [100.0, -100.0]])
    out = trimmed_mean(stack, trim=1)
    np.testing.assert_allclose(out, [2.5, 5.5])


def test_aggregate_pytrees_roundtrip():
    ups = rand_updates(3)
    agg = aggregate_pytrees(ups, method="fedavg")
    assert agg["a"].shape == (8, 8) and agg["b"].shape == (5,)
    params = {"a": jnp.zeros((8, 8)), "b": jnp.zeros((5,))}
    new = apply_update(params, agg, scale=2.0)
    np.testing.assert_allclose(new["a"], 2 * agg["a"], atol=1e-6)


@given(
    k=st.integers(2, 9),
    d=st.integers(1, 50),
    use_weights=st.booleans(),
)
@settings(max_examples=25, deadline=None)
def test_property_fedavg_convexity(k, d, use_weights):
    """FedAvg output lies in the convex hull per coordinate."""
    rng = np.random.default_rng(k * 100 + d)
    stack = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    w = jnp.asarray(np.abs(rng.normal(size=k)) + 0.01) if use_weights else None
    out = np.asarray(fedavg(stack, w))
    lo, hi = np.asarray(stack).min(0), np.asarray(stack).max(0)
    assert np.all(out >= lo - 1e-4) and np.all(out <= hi + 1e-4)


@given(k=st.integers(2, 9), d=st.integers(1, 40))
@settings(max_examples=25, deadline=None)
def test_property_cwmed_permutation_invariant(k, d):
    rng = np.random.default_rng(k * 7 + d)
    stack = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    perm = rng.permutation(k)
    np.testing.assert_allclose(cwmed(stack), cwmed(stack[perm]), atol=1e-6)
