"""Chain storage pattern (paper §III.A) — unit + hypothesis property tests."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.blockchain import Chain, LayoutError, pytree_digest
from repro.core.storage import OffChainStore


def model(v=0.0):
    return {"w": jnp.full((4, 4), v), "b": jnp.zeros((4,))}


def update(v=1.0):
    return {"w": jnp.full((4, 4), v * 0.1), "b": jnp.full((4,), v)}


def run_rounds(chain: Chain, rounds: int):
    for t in range(rounds):
        for i in range(chain.k):
            chain.append_update(update(i), uploader=i, score=0.5 + 0.01 * i)
        chain.append_model(model(t + 1), t + 1)


def test_layout_formula():
    k = 3
    chain = Chain(k)
    chain.append_model(model(), 0)
    run_rounds(chain, 2)
    # model block of round t at height t*(k+1)
    for t in range(3):
        blk = chain.blocks[chain.model_index(t)]
        assert blk.kind == "model" and blk.round == t
    lo, hi = chain.update_index_range(0)
    assert (lo, hi) == (1, 3)
    for idx in range(lo, hi + 1):
        assert chain.blocks[idx].kind == "update"


def test_latest_model_o1():
    chain = Chain(2)
    chain.append_model(model(0), 0)
    run_rounds(chain, 5)
    t, m = chain.latest_model()
    assert t == 5
    assert float(m["w"][0, 0]) == 5.0


def test_append_model_requires_k_updates():
    chain = Chain(3)
    chain.append_model(model(), 0)
    chain.append_update(update(), 0, 0.5)
    with pytest.raises(LayoutError):
        chain.append_model(model(1), 1)


def test_too_many_updates_rejected():
    chain = Chain(2)
    chain.append_model(model(), 0)
    chain.append_update(update(), 0, 0.5)
    chain.append_update(update(), 1, 0.5)
    with pytest.raises(LayoutError):
        chain.append_update(update(), 2, 0.5)


def test_verify_detects_tamper():
    chain = Chain(2)
    chain.append_model(model(), 0)
    run_rounds(chain, 2)
    assert chain.verify()
    # tamper with a stored update payload
    chain.blocks[1].payload = update(99.0)
    assert not chain.verify()


def test_verify_detects_reorder():
    chain = Chain(2)
    chain.append_model(model(), 0)
    run_rounds(chain, 2)
    chain.blocks[1], chain.blocks[2] = chain.blocks[2], chain.blocks[1]
    assert not chain.verify()


def test_prune_keeps_latest_and_headers():
    chain = Chain(2)
    chain.append_model(model(), 0)
    run_rounds(chain, 4)
    before = chain.storage_bytes()
    dropped = chain.prune(keep_rounds=1)
    assert dropped > 0
    assert chain.storage_bytes() < before
    # latest model still there, historical payload gone
    t, m = chain.latest_model()
    assert t == 4
    with pytest.raises(KeyError):
        chain.model_at_round(0)
    # hash chain still verifiable after pruning
    assert chain.verify()


def test_off_chain_store_roundtrip(tmp_path):
    store = OffChainStore(str(tmp_path / "blobs"))
    chain = Chain(2, off_chain_store=store)
    chain.append_model(model(7.0), 0)
    run_rounds(chain, 2)
    # payloads live off-chain; block payloads are None
    assert all(b.payload is None for b in chain.blocks)
    t, m = chain.latest_model()
    # content-addressed store dedupes identical payloads
    unique = len({b.payload_digest for b in chain.blocks})
    assert t == 2 and store.size() == unique
    assert chain.model_at_round(0)["w"][0, 0] == 7.0


def test_failback_to_historical_model():
    chain = Chain(2)
    chain.append_model(model(0), 0)
    run_rounds(chain, 3)
    # §IV.C: after an attack, any historical model is recoverable
    m1 = chain.model_at_round(1)
    assert float(m1["w"][0, 0]) == 1.0


@given(k=st.integers(1, 6), rounds=st.integers(0, 6))
@settings(max_examples=20, deadline=None)
def test_property_chain_invariants(k, rounds):
    chain = Chain(k)
    chain.append_model(model(), 0)
    run_rounds(chain, rounds)
    assert chain.verify()
    assert chain.height == (rounds) * (k + 1) + 1
    assert chain.latest_model()[0] == rounds
    # every model block index is a multiple of k+1
    for blk in chain.blocks:
        if blk.kind == "model":
            assert blk.index % (k + 1) == 0


# ----------------------------------------------------------------------
# tiered chains (repro.fl.hier): committee block in the enforced layout
# ----------------------------------------------------------------------
def committee_record(s=2, q=3):
    return {"members": np.arange(q), "scores": np.zeros((s, q), np.float32),
            "accepted": np.ones(s, bool)}


def run_tiered_rounds(chain: Chain, rounds: int):
    for t in range(rounds):
        for i in range(chain.k):
            chain.append_update(update(i), uploader=i, score=0.5)
        chain.append_committee(committee_record(s=chain.k))
        chain.append_model(model(t + 1), t + 1)


def test_tiered_layout_formula():
    chain = Chain(2, tier2_block=True)
    assert chain.period == 4
    chain.append_model(model(), 0)
    run_tiered_rounds(chain, 2)
    assert chain.verify()
    assert chain.height == 2 * 4 + 1
    for t in range(2):
        assert chain.blocks[chain.model_index(t)].kind == "model"
        assert chain.blocks[chain.committee_index(t)].kind == "committee"
        assert chain.committee_index(t) == chain.update_index_range(t)[1] + 1
        rec = chain.committee_at_round(t)
        assert rec["scores"].shape == (2, 3)
    assert chain.latest_model()[0] == 2


def test_flat_chain_has_no_committee_blocks():
    chain = Chain(2)
    chain.append_model(model(), 0)
    with pytest.raises(LayoutError, match="flat chain"):
        chain.committee_index(0)
    with pytest.raises(LayoutError):
        chain.append_committee(committee_record())


def test_tiered_committee_block_is_mandatory():
    chain = Chain(2, tier2_block=True)
    chain.append_model(model(), 0)
    chain.append_update(update(), 0, 0.5)
    # too early: an update slot is still open
    with pytest.raises(LayoutError):
        chain.append_committee(committee_record())
    chain.append_update(update(), 1, 0.5)
    # model before the committee block: the audit trail can't be skipped
    with pytest.raises(LayoutError):
        chain.append_model(model(1), 1)
    chain.append_committee(committee_record())
    with pytest.raises(LayoutError):     # exactly one committee block
        chain.append_committee(committee_record())
    chain.append_model(model(1), 1)
    assert chain.verify()


def test_tiered_verify_detects_committee_tamper():
    chain = Chain(2, tier2_block=True)
    chain.append_model(model(), 0)
    run_tiered_rounds(chain, 1)
    assert chain.verify()
    chain.blocks[3].payload = committee_record(s=2, q=4)
    assert not chain.verify()


def test_tiered_committee_never_codec_encoded():
    class _BoomCodec:
        def encode(self, tree):
            raise AssertionError("committee records must not hit the codec")

        def decode(self, blob):
            return blob

    chain = Chain(1, update_codec=_BoomCodec(), tier2_block=True)
    chain.append_model(model(), 0)
    chain.append_update(update(), 0, 0.5, encoded=True)
    blk = chain.append_committee(committee_record(s=1))
    assert not blk.encoded
    np.testing.assert_array_equal(
        chain.committee_at_round(0)["accepted"], committee_record(1)["accepted"]
    )


@given(k=st.integers(1, 5), rounds=st.integers(0, 5))
@settings(max_examples=20, deadline=None)
def test_property_tiered_chain_invariants(k, rounds):
    chain = Chain(k, tier2_block=True)
    chain.append_model(model(), 0)
    run_tiered_rounds(chain, rounds)
    assert chain.verify()
    assert chain.height == rounds * (k + 2) + 1
    assert chain.latest_model()[0] == rounds
    for blk in chain.blocks:
        pos = blk.index % chain.period
        assert blk.kind == ("model" if pos == 0
                            else "update" if pos <= k else "committee")


def test_digest_sensitivity():
    a = model(1.0)
    b = model(1.0)
    assert pytree_digest(a) == pytree_digest(b)
    b["w"] = b["w"].at[0, 0].set(1.0001)
    assert pytree_digest(a) != pytree_digest(b)


def test_encoded_update_requires_codec():
    chain = Chain(2)
    chain.append_model(model(), 0)
    with pytest.raises(ValueError, match="codec"):
        chain.append_update({"q": jnp.zeros(4, jnp.int8)}, 0, 0.5, encoded=True)


def test_codec_chain_roundtrip_and_tamper():
    class _DoubleCodec:  # toy codec: enough to exercise encode/decode wiring
        def encode(self, tree):
            return {k: v * 2 for k, v in tree.items()}

        def decode(self, blob):
            return {k: v / 2 for k, v in blob.items()}

    chain = Chain(1, update_codec=_DoubleCodec())
    chain.append_model(model(), 0)
    chain.append_update(update(3.0), uploader=0, score=0.9)
    blk = chain.blocks[1]
    assert blk.encoded
    np.testing.assert_allclose(
        chain.raw_payload(blk)["b"], update(3.0)["b"] * 2
    )
    np.testing.assert_allclose(
        chain.update_payloads_at_round(0)[0]["b"], update(3.0)["b"]
    )
    assert chain.verify()
    blk.encoded = False          # the flag is hashed: tampering must show
    assert not chain.verify()
