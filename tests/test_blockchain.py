"""Chain storage pattern (paper §III.A) — unit + hypothesis property tests."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.blockchain import Chain, LayoutError, pytree_digest
from repro.core.storage import OffChainStore


def model(v=0.0):
    return {"w": jnp.full((4, 4), v), "b": jnp.zeros((4,))}


def update(v=1.0):
    return {"w": jnp.full((4, 4), v * 0.1), "b": jnp.full((4,), v)}


def run_rounds(chain: Chain, rounds: int):
    for t in range(rounds):
        for i in range(chain.k):
            chain.append_update(update(i), uploader=i, score=0.5 + 0.01 * i)
        chain.append_model(model(t + 1), t + 1)


def test_layout_formula():
    k = 3
    chain = Chain(k)
    chain.append_model(model(), 0)
    run_rounds(chain, 2)
    # model block of round t at height t*(k+1)
    for t in range(3):
        blk = chain.blocks[chain.model_index(t)]
        assert blk.kind == "model" and blk.round == t
    lo, hi = chain.update_index_range(0)
    assert (lo, hi) == (1, 3)
    for idx in range(lo, hi + 1):
        assert chain.blocks[idx].kind == "update"


def test_latest_model_o1():
    chain = Chain(2)
    chain.append_model(model(0), 0)
    run_rounds(chain, 5)
    t, m = chain.latest_model()
    assert t == 5
    assert float(m["w"][0, 0]) == 5.0


def test_append_model_requires_k_updates():
    chain = Chain(3)
    chain.append_model(model(), 0)
    chain.append_update(update(), 0, 0.5)
    with pytest.raises(LayoutError):
        chain.append_model(model(1), 1)


def test_too_many_updates_rejected():
    chain = Chain(2)
    chain.append_model(model(), 0)
    chain.append_update(update(), 0, 0.5)
    chain.append_update(update(), 1, 0.5)
    with pytest.raises(LayoutError):
        chain.append_update(update(), 2, 0.5)


def test_verify_detects_tamper():
    chain = Chain(2)
    chain.append_model(model(), 0)
    run_rounds(chain, 2)
    assert chain.verify()
    # tamper with a stored update payload
    chain.blocks[1].payload = update(99.0)
    assert not chain.verify()


def test_verify_detects_reorder():
    chain = Chain(2)
    chain.append_model(model(), 0)
    run_rounds(chain, 2)
    chain.blocks[1], chain.blocks[2] = chain.blocks[2], chain.blocks[1]
    assert not chain.verify()


def test_prune_keeps_latest_and_headers():
    chain = Chain(2)
    chain.append_model(model(), 0)
    run_rounds(chain, 4)
    before = chain.storage_bytes()
    dropped = chain.prune(keep_rounds=1)
    assert dropped > 0
    assert chain.storage_bytes() < before
    # latest model still there, historical payload gone
    t, m = chain.latest_model()
    assert t == 4
    with pytest.raises(KeyError):
        chain.model_at_round(0)
    # hash chain still verifiable after pruning
    assert chain.verify()


def test_off_chain_store_roundtrip(tmp_path):
    store = OffChainStore(str(tmp_path / "blobs"))
    chain = Chain(2, off_chain_store=store)
    chain.append_model(model(7.0), 0)
    run_rounds(chain, 2)
    # payloads live off-chain; block payloads are None
    assert all(b.payload is None for b in chain.blocks)
    t, m = chain.latest_model()
    # content-addressed store dedupes identical payloads
    unique = len({b.payload_digest for b in chain.blocks})
    assert t == 2 and store.size() == unique
    assert chain.model_at_round(0)["w"][0, 0] == 7.0


def test_failback_to_historical_model():
    chain = Chain(2)
    chain.append_model(model(0), 0)
    run_rounds(chain, 3)
    # §IV.C: after an attack, any historical model is recoverable
    m1 = chain.model_at_round(1)
    assert float(m1["w"][0, 0]) == 1.0


@given(k=st.integers(1, 6), rounds=st.integers(0, 6))
@settings(max_examples=20, deadline=None)
def test_property_chain_invariants(k, rounds):
    chain = Chain(k)
    chain.append_model(model(), 0)
    run_rounds(chain, rounds)
    assert chain.verify()
    assert chain.height == (rounds) * (k + 1) + 1
    assert chain.latest_model()[0] == rounds
    # every model block index is a multiple of k+1
    for blk in chain.blocks:
        if blk.kind == "model":
            assert blk.index % (k + 1) == 0


def test_digest_sensitivity():
    a = model(1.0)
    b = model(1.0)
    assert pytree_digest(a) == pytree_digest(b)
    b["w"] = b["w"].at[0, 0].set(1.0001)
    assert pytree_digest(a) != pytree_digest(b)
