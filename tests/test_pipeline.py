"""The composable round pipeline: registries, stage swapping through the
public API, per-stage timings, and the repro.api facade."""
import numpy as np
import pytest

from repro.api import build_config, build_runtime
from repro.core.blockchain import UPDATE
from repro.data import make_femnist_like
from repro.fl import (
    BFLCConfig,
    BFLCRuntime,
    FLConfig,
    FLTrainer,
    femnist_adapter,
)
from repro.fl import pipeline as pl


@pytest.fixture(scope="module")
def small_ds():
    return make_femnist_like(
        num_clients=24, mean_samples=40, test_size=200, seed=3
    )


@pytest.fixture(scope="module")
def adapter():
    return femnist_adapter(width=8)


CFG_KW = dict(active_proportion=0.5, committee_fraction=0.3,
              k_updates=4, local_steps=2, local_batch=8, seed=0)


def test_registries_hold_defaults():
    assert set(pl.STAGE_KINDS) == {
        "sampler", "local_trainer", "validator", "packer", "aggregator",
        "elector", "rewarder",
    }
    assert {"active", "uniform"} <= set(pl.SAMPLERS)
    assert "local_sgd" in pl.LOCAL_TRAINERS
    assert {"committee", "accept_all"} <= set(pl.VALIDATORS)
    assert {"top_k", "top_k_int8", "all"} <= set(pl.PACKERS)
    # the PR-1 engines are two registered Aggregator implementations
    assert {"pytree", "fused_int8"} <= set(pl.AGGREGATORS)
    assert {"by_candidates", "none"} <= set(pl.ELECTORS)
    assert {"proportional", "none"} <= set(pl.REWARDERS)


def test_resolve_unknown_name_lists_registered():
    with pytest.raises(KeyError, match="no aggregator named 'bogus'"):
        pl.resolve("aggregator", "bogus")
    with pytest.raises(ValueError, match="unknown stage kinds"):
        pl.build_pipeline(pl.default_stage_names(BFLCConfig()),
                          {"not_a_stage": "x"})


def test_default_wiring_follows_config():
    names = pl.default_stage_names(BFLCConfig())
    assert names["packer"] == "top_k" and names["aggregator"] == "pytree"
    q = pl.default_stage_names(
        BFLCConfig(quantize_chain=True, use_kernels=True)
    )
    assert q["packer"] == "top_k_int8" and q["aggregator"] == "fused_int8"


def test_custom_registered_stage_swaps_in(small_ds, adapter):
    """Acceptance: a custom registered stage (a no-committee Packer that
    reproduces Basic FL's unweighted selection) drops in via the runtime
    facade without modifying repro.fl.pipeline internals."""

    @pl.register("packer", "first_k_no_committee")
    def pack_first_k(ctx):
        cfg = ctx.cfg
        ids = list(ctx.updates)[: cfg.k_updates]
        while len(ids) < cfg.k_updates:   # chain layout needs exactly k
            ids.append(ids[0])
        ctx.packed_ids = ids
        ctx.packed_scores = [0.0] * len(ids)
        ctx.packed_updates = [ctx.updates[u] for u in ids]
        ctx.weights = None                # unweighted, like Basic FL
        for i, u in enumerate(ids):
            ctx.chain.append_update(ctx.packed_updates[i], u, 0.0)

    rt = BFLCRuntime(adapter, small_ds, BFLCConfig(**CFG_KW),
                     stages={"packer": "first_k_no_committee",
                             "elector": "none"})
    c0 = list(rt.committee)
    log = rt.run_round()
    assert rt.chain.verify()
    assert rt.chain.height == 1 + (CFG_KW["k_updates"] + 1)
    assert rt.committee == c0             # elector "none" kept it static
    packed = [b.uploader for b in rt.chain.blocks if b.kind == UPDATE]
    assert len(packed) == CFG_KW["k_updates"]
    assert log.mean_packed_score == 0.0   # scores bypassed the committee


def test_top_k_packer_without_consensus_raises(small_ds, adapter):
    rt = BFLCRuntime(adapter, small_ds, BFLCConfig(**CFG_KW),
                     stages={"validator": "accept_all"})
    with pytest.raises(RuntimeError, match="consensus-producing validator"):
        rt.run_round()


def test_callable_stage_override(small_ds, adapter):
    seen = []

    def spy_rewarder(ctx):
        seen.append(ctx.round)

    rt = BFLCRuntime(adapter, small_ds, BFLCConfig(**CFG_KW),
                     stages={"rewarder": spy_rewarder})
    rt.run_round()
    assert seen == [0]


def test_stage_timings_populated(small_ds, adapter):
    rt = BFLCRuntime(adapter, small_ds, BFLCConfig(**CFG_KW))
    rt.run_round()
    (timings,) = rt.stage_timings
    assert set(pl.STAGE_TIMING_KEYS) <= set(timings)
    assert all(v >= 0 for v in timings.values())

    fl = FLTrainer(adapter, small_ds,
                   FLConfig(active_proportion=0.4, local_steps=2,
                            local_batch=8, seed=0))
    fl.run_round()
    assert "train" in fl.stage_timings[0]


def test_api_build_runtime_dispatch(small_ds, adapter):
    rt = build_runtime(adapter, small_ds, dict(CFG_KW))
    assert isinstance(rt, BFLCRuntime)
    log = rt.run_round()
    assert rt.chain.verify() and log.round == 0

    fl = build_runtime(adapter, small_ds,
                       {"active_proportion": 0.4, "local_steps": 2,
                        "local_batch": 8, "seed": 0}, baseline=True)
    assert isinstance(fl, FLTrainer)
    fl.run_round()
    assert 0.0 <= fl.evaluate() <= 1.0

    assert isinstance(build_config(None), BFLCConfig)
    assert isinstance(build_config(FLConfig()), FLConfig)
    assert isinstance(build_config(FLConfig(), baseline=True), FLConfig)
    with pytest.raises(TypeError):
        build_config(42)
    with pytest.raises(ValueError, match="contradicts"):
        build_config(BFLCConfig(), baseline=True)
