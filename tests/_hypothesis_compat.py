"""Degrade gracefully when ``hypothesis`` is not installed.

Test modules import ``given``/``settings``/``st`` from here instead of from
hypothesis directly.  With hypothesis present this is a pass-through; without
it the property tests are skipped individually while the rest of the module
still collects and runs (a bare ``import hypothesis`` at module scope used to
error out collection for seven modules).
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: any attribute access,
        call, or builder chain (``st.integers(1, 8).map(...)``) yields the
        same inert object — strategies are only built at decoration time,
        never drawn from, because the test body is skipped."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _AnyStrategy()

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*args, **kwargs):
        return lambda fn: fn
