"""Hypergeometric attack analysis (paper §IV.C, Fig. 3)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.security import attack_success_probability, fig3_grid


def test_zero_when_no_malicious():
    assert attack_success_probability(1000, 0.1, 0.0) == 0.0


def test_one_when_all_malicious():
    assert attack_success_probability(1000, 0.1, 1.0) == pytest.approx(1.0)


def test_paper_51_percent_claim():
    """Fig. 3: 'only when the malicious percentage greater than 50%, the
    attack success probability could be greater than 0 markedly'."""
    A = 1000
    for p in (0.05, 0.1, 0.3):
        assert attack_success_probability(A, p, 0.3) < 1e-3
        assert attack_success_probability(A, p, 0.45) < 0.2
        assert attack_success_probability(A, p, 0.60) > 0.8


def test_majority_threshold_is_half_committee():
    # tiny exact case: A=4, committee=2, malicious=2 -> need BOTH seats
    # P[X=2] = C(2,2)C(2,0)/C(4,2) = 1/6
    assert attack_success_probability(4, 0.5, 0.5) == pytest.approx(1 / 6)


@given(
    q1=st.floats(0.05, 0.45), q2=st.floats(0.5, 0.95),
    p=st.floats(0.05, 0.4),
)
@settings(max_examples=30, deadline=None)
def test_property_monotone_in_q(q1, q2, p):
    A = 500
    assert attack_success_probability(A, p, q1) <= \
        attack_success_probability(A, p, q2) + 1e-12


@given(p=st.floats(0.02, 0.5), q=st.floats(0.0, 1.0))
@settings(max_examples=50, deadline=None)
def test_property_valid_probability(p, q):
    v = attack_success_probability(300, p, q)
    assert -1e-12 <= v <= 1 + 1e-9


def test_larger_committee_reduces_variance():
    """At q just under 1/2, bigger committees suppress attack probability
    (concentration) — the paper's motivation for election by score."""
    A = 1000
    small = attack_success_probability(A, 0.02, 0.45)
    large = attack_success_probability(A, 0.4, 0.45)
    assert large < small


def test_fig3_grid_shape():
    g = fig3_grid(A=200, ps=np.array([0.1, 0.2]), qs=np.array([0.2, 0.5, 0.8]))
    assert g["prob"].shape == (2, 3)
    assert np.all(np.diff(g["prob"], axis=1) >= -1e-9)  # monotone in q
