"""Attack-scenario regression gates (paper §V qualitative claim).

For EVERY entry in the ``ATTACKS`` registry (gaussian noise, sign-flip,
scaled update) at the paper's malicious proportion (30%, within Fig. 4's
tolerated range), a full BFLC round sequence must keep malicious updates
out of the packed set at a rate far below the committee-free ``accept_all``
baseline — which, by construction, packs malicious updates at the
malicious-participation rate.

The model is warm-started first: committee validation discriminates only
once honest scores separate from poisoned ones (the paper's Fig. 4 defense
operates on a converging model; the cold-start window is a known
vulnerability reported separately).  ``collusion=False`` isolates the
validator's filtering — the collusive strengthened attack (§V.B) is the
election-takeover story exercised by Fig. 3/4 benchmarks, not this gate.

Everything is seeded, so the rates below are deterministic regression pins,
not statistical tests.
"""
import pytest

from repro.api import build_runtime
from repro.core.attacks import ATTACKS
from repro.data import make_femnist_like
from repro.fl import femnist_adapter
from repro.fl.baselines import train_standalone

MALICIOUS_FRACTION = 0.3
ROUNDS = 6
K = 8

CFG = dict(active_proportion=0.7, committee_fraction=0.4, k_updates=K,
           local_steps=20, local_batch=32, local_lr=0.05, collusion=False,
           malicious_fraction=MALICIOUS_FRACTION, attack_sigma=2.0, seed=1)


@pytest.fixture(scope="module")
def ds():
    return make_femnist_like(num_clients=24, mean_samples=60,
                             test_size=300, seed=3)


@pytest.fixture(scope="module")
def adapter():
    return femnist_adapter(width=8)


@pytest.fixture(scope="module")
def warm_params(ds, adapter):
    warm, _ = train_standalone(adapter, ds, steps=150, batch=32, lr=0.05,
                               eval_every=1000)
    return warm


def _bflc_packed_malicious_rate(ds, adapter, warm, attack: str) -> float:
    rt = build_runtime(adapter, ds, dict(CFG, attack=attack),
                       initial_params=warm)
    logs = rt.run(ROUNDS, eval_every=ROUNDS + 1)
    assert rt.chain.verify()
    # round 0-1 may still be stabilizing (first elected committees);
    # the gate is the steady-state filtering rate, as in Fig. 4
    later = logs[2:]
    return sum(l.packed_malicious for l in later) / (K * len(later))


def _accept_all_packed_malicious_rate(ds, adapter, warm, attack: str) -> float:
    packed = []
    bl = build_runtime(
        adapter, ds,
        dict(active_proportion=0.7, local_steps=20, local_batch=32,
             local_lr=0.05, malicious_fraction=MALICIOUS_FRACTION,
             attack=attack, attack_sigma=2.0, seed=1),
        baseline=True, initial_params=warm,
        # the baseline rewarder is a no-op slot: hook it to capture what
        # the accept_all validator + pack_all packer let through
        stages={"rewarder": lambda ctx: packed.append(list(ctx.packed_ids))},
    )
    bl.run(ROUNDS, eval_every=ROUNDS + 1)
    mal = bl.malicious
    return (sum(sum(1 for u in r if u in mal) for r in packed)
            / sum(len(r) for r in packed))


# per-attack packed-malicious gates.  The attacks are not equally
# detectable by design: gaussian (the paper's §V.B attack, ref=params)
# and sign_flip corrupt candidates at model magnitude, so committee
# scores separate sharply; "scaled" replaces the update with noise at
# *update* magnitude (10x mean|u| on a warm-started model), the
# stealthiest registered mode — its candidates barely move validation
# accuracy, so the committee's packed rate sits closer to (but below)
# the 30% participation rate.  The gates are seeded one-slot-granular
# pins over 4 rounds x k=8 = 32 packed slots (1 slot = 0.031).
GATES = {"gaussian": 0.2, "sign_flip": 0.2, "scaled": 0.25}


# ----------------------------------------------------------------------
# hierarchical rounds: a fully colluding sub-committee (§V.B strengthened
# attack applied to one tier-1 slice) must be caught at tier 2
# ----------------------------------------------------------------------
HIER_TIERS = 2
HIER_ROUNDS = 5
# 24 clients, everyone active, q_committee = 4 -> pool of 20, 2 slices of
# 10 (3-member sub-committee + 7 trainers each).  sigma = 6: averaging the
# slice's 7 iid noise updates divides the applied magnitude by ~sqrt(7),
# so per-update noise must be well above the flat gates' sigma = 2 for the
# *sub-aggregate* to be a real poison (the flat gates score updates
# individually; tier 2 scores the slice mean)
HIER_CFG = dict(active_proportion=1.0, committee_fraction=1 / 6,
                k_updates=4, local_steps=20, local_batch=32, local_lr=0.05,
                collusion=True, malicious_fraction=0.0, attack="gaussian",
                attack_sigma=6.0, seed=1)


def _colluding_slice_runtime(ds, adapter, warm):
    """Tiered runtime where slice 0 is wholly compromised every round:
    all its trainers poison their updates AND its whole sub-committee
    colludes (CollusionPolicy high scores), so the poisoned sub-aggregate
    sails through its own tier-1 vote — the scenario only tier-2
    filtering can catch."""
    from repro.core.attacks import poison_membership
    from repro.fl.hier import sample_tiered

    def colluding_sampler(ctx):
        sample_tiered(ctx)
        if ctx.cohort == 0:
            sl = ctx.hier.slices[0]
            poison_membership(ctx.manager,
                              list(sl.trainers) + list(sl.committee))

    return build_runtime(adapter, ds, dict(HIER_CFG), tiers=HIER_TIERS,
                         initial_params=warm,
                         stages={"sampler": colluding_sampler})


@pytest.mark.slow
def test_colluding_sub_committee_caught_at_tier2(ds, adapter, warm_params):
    rt = _colluding_slice_runtime(ds, adapter, warm_params)
    logs = rt.run(HIER_ROUNDS, eval_every=HIER_ROUNDS + 1)
    assert rt.chain.verify()

    # the compromised slice's rep IS malicious and DID pass tier 1 (its
    # colluding sub-committee accepted it): the final round's marking is
    # still live, so the attack demonstrably presented a poisoned
    # sub-aggregate to tier 2
    mal = {i for i, nd in rt.manager.nodes.items() if nd.is_malicious}
    assert mal, "poison_membership never ran"
    last = rt.chain.committee_at_round(HIER_ROUNDS - 1)
    assert any(int(u) in mal for u in last["uploaders"])

    later = logs[1:]
    slots = HIER_TIERS * len(later)
    hier_rate = sum(l.packed_malicious for l in later) / slots
    # a tier-2-free hierarchy packs the poisoned sub-aggregate every
    # round: rate 1/S.  accept_all semantics pack malicious at the
    # participation rate (half the trainers).  The tier-2 committee must
    # keep the poisoned sub-aggregate out of the packed set.
    no_tier2_rate = 1.0 / HIER_TIERS
    assert hier_rate < no_tier2_rate / 2, (hier_rate, no_tier2_rate)
    assert hier_rate <= 0.2, hier_rate
    # and the chain records the rejection: the compromised slice's
    # sub-aggregate is marked not-accepted in the tier-2 audit block
    rejected_rounds = sum(
        1 for t in range(1, HIER_ROUNDS)
        if not all(rt.chain.committee_at_round(t)["accepted"])
    )
    assert rejected_rounds >= (HIER_ROUNDS - 1) // 2, rejected_rounds


@pytest.mark.slow
@pytest.mark.parametrize("attack", sorted(ATTACKS))
def test_committee_filters_attack_but_accept_all_does_not(
        ds, adapter, warm_params, attack):
    bflc_rate = _bflc_packed_malicious_rate(ds, adapter, warm_params, attack)
    accept_rate = _accept_all_packed_malicious_rate(
        ds, adapter, warm_params, attack)
    # accept_all packs malicious at (roughly) the participation rate —
    # no filtering whatsoever
    assert accept_rate > 0.2, (attack, accept_rate)
    # the committee keeps them out of the packed set
    assert bflc_rate < GATES[attack], (attack, bflc_rate)
    assert bflc_rate < accept_rate, (attack, bflc_rate, accept_rate)
