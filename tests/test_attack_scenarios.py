"""Attack-scenario regression gates (paper §V qualitative claim).

For EVERY entry in the ``ATTACKS`` registry (gaussian noise, sign-flip,
scaled update) at the paper's malicious proportion (30%, within Fig. 4's
tolerated range), a full BFLC round sequence must keep malicious updates
out of the packed set at a rate far below the committee-free ``accept_all``
baseline — which, by construction, packs malicious updates at the
malicious-participation rate.

The model is warm-started first: committee validation discriminates only
once honest scores separate from poisoned ones (the paper's Fig. 4 defense
operates on a converging model; the cold-start window is a known
vulnerability reported separately).  ``collusion=False`` isolates the
validator's filtering — the collusive strengthened attack (§V.B) is the
election-takeover story exercised by Fig. 3/4 benchmarks, not this gate.

Everything is seeded, so the rates below are deterministic regression pins,
not statistical tests.
"""
import pytest

from repro.api import build_runtime
from repro.core.attacks import ATTACKS
from repro.data import make_femnist_like
from repro.fl import femnist_adapter
from repro.fl.baselines import train_standalone

MALICIOUS_FRACTION = 0.3
ROUNDS = 6
K = 8

CFG = dict(active_proportion=0.7, committee_fraction=0.4, k_updates=K,
           local_steps=20, local_batch=32, local_lr=0.05, collusion=False,
           malicious_fraction=MALICIOUS_FRACTION, attack_sigma=2.0, seed=1)


@pytest.fixture(scope="module")
def ds():
    return make_femnist_like(num_clients=24, mean_samples=60,
                             test_size=300, seed=3)


@pytest.fixture(scope="module")
def adapter():
    return femnist_adapter(width=8)


@pytest.fixture(scope="module")
def warm_params(ds, adapter):
    warm, _ = train_standalone(adapter, ds, steps=150, batch=32, lr=0.05,
                               eval_every=1000)
    return warm


def _bflc_packed_malicious_rate(ds, adapter, warm, attack: str) -> float:
    rt = build_runtime(adapter, ds, dict(CFG, attack=attack),
                       initial_params=warm)
    logs = rt.run(ROUNDS, eval_every=ROUNDS + 1)
    assert rt.chain.verify()
    # round 0-1 may still be stabilizing (first elected committees);
    # the gate is the steady-state filtering rate, as in Fig. 4
    later = logs[2:]
    return sum(l.packed_malicious for l in later) / (K * len(later))


def _accept_all_packed_malicious_rate(ds, adapter, warm, attack: str) -> float:
    packed = []
    bl = build_runtime(
        adapter, ds,
        dict(active_proportion=0.7, local_steps=20, local_batch=32,
             local_lr=0.05, malicious_fraction=MALICIOUS_FRACTION,
             attack=attack, attack_sigma=2.0, seed=1),
        baseline=True, initial_params=warm,
        # the baseline rewarder is a no-op slot: hook it to capture what
        # the accept_all validator + pack_all packer let through
        stages={"rewarder": lambda ctx: packed.append(list(ctx.packed_ids))},
    )
    bl.run(ROUNDS, eval_every=ROUNDS + 1)
    mal = bl.malicious
    return (sum(sum(1 for u in r if u in mal) for r in packed)
            / sum(len(r) for r in packed))


# per-attack packed-malicious gates.  The attacks are not equally
# detectable by design: gaussian (the paper's §V.B attack, ref=params)
# and sign_flip corrupt candidates at model magnitude, so committee
# scores separate sharply; "scaled" replaces the update with noise at
# *update* magnitude (10x mean|u| on a warm-started model), the
# stealthiest registered mode — its candidates barely move validation
# accuracy, so the committee's packed rate sits closer to (but below)
# the 30% participation rate.  The gates are seeded one-slot-granular
# pins over 4 rounds x k=8 = 32 packed slots (1 slot = 0.031).
GATES = {"gaussian": 0.2, "sign_flip": 0.2, "scaled": 0.25}


@pytest.mark.slow
@pytest.mark.parametrize("attack", sorted(ATTACKS))
def test_committee_filters_attack_but_accept_all_does_not(
        ds, adapter, warm_params, attack):
    bflc_rate = _bflc_packed_malicious_rate(ds, adapter, warm_params, attack)
    accept_rate = _accept_all_packed_malicious_rate(
        ds, adapter, warm_params, attack)
    # accept_all packs malicious at (roughly) the participation rate —
    # no filtering whatsoever
    assert accept_rate > 0.2, (attack, accept_rate)
    # the committee keeps them out of the packed set
    assert bflc_rate < GATES[attack], (attack, bflc_rate)
    assert bflc_rate < accept_rate, (attack, bflc_rate, accept_rate)
