"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


KS = (2, 3, 5, 8, 17)
DS = (2048, 4096, 6144)          # block-aligned
DS_RAGGED = (1, 100, 2049, 5000)  # need padding


@pytest.mark.parametrize("K", KS)
@pytest.mark.parametrize("D", DS + DS_RAGGED)
def test_fedavg_agg_matches_ref(K, D):
    key = jax.random.PRNGKey(K * 1000 + D)
    stack = jax.random.normal(key, (K, D), jnp.float32)
    w = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(1), (K,)))
    np.testing.assert_allclose(
        ops.fedavg_agg(stack, w), ref.fedavg_agg_ref(stack, w),
        atol=1e-5, rtol=1e-5,
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fedavg_agg_dtypes(dtype):
    stack = jax.random.normal(jax.random.PRNGKey(0), (4, 2048)).astype(dtype)
    w = jnp.full((4,), 0.25, jnp.float32)
    out = ops.fedavg_agg(stack, w)
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(
        out, ref.fedavg_agg_ref(stack, w), atol=2e-2,
    )


@pytest.mark.parametrize("K", KS)
@pytest.mark.parametrize("D", (2048, 2049, 5000))
def test_cwmed_matches_ref(K, D):
    key = jax.random.PRNGKey(K + D)
    stack = jax.random.normal(key, (K, D), jnp.float32)
    np.testing.assert_allclose(
        ops.cwmed(stack), ref.cwmed_ref(stack), atol=1e-6,
    )


def test_cwmed_sorting_network_handles_ties():
    stack = jnp.ones((6, 2048))
    np.testing.assert_allclose(ops.cwmed(stack), jnp.ones(2048))


@pytest.mark.parametrize("D", (2048, 4096, 5000, 100))
def test_quantize_roundtrip(D):
    x = jax.random.normal(jax.random.PRNGKey(D), (D,)) * 5
    q, s, d = ops.quantize(x)
    xq = ops.dequantize(q, s, d)
    rel = float(jnp.abs(x - xq).max() / jnp.abs(x).max())
    assert rel < 0.02


def test_quantize_matches_ref():
    x = jax.random.normal(jax.random.PRNGKey(0), (4096,))
    q, s, _ = ops.quantize(x)
    qr, sr = ref.quantize_ref(x)
    np.testing.assert_array_equal(q, qr)
    np.testing.assert_allclose(s, sr, rtol=1e-6)
    np.testing.assert_allclose(
        ops.dequantize(q, s, 4096), ref.dequantize_ref(qr, sr), atol=1e-6
    )


def test_quantize_pytree_roundtrip():
    tree = {"a": jax.random.normal(jax.random.PRNGKey(1), (33, 77)),
            "b": {"c": jnp.linspace(-2, 2, 101)}}
    blob, unravel = ops.quantize_pytree(tree)
    out = ops.dequantize_pytree(blob, unravel)
    for k in ("a",):
        np.testing.assert_allclose(out[k], tree[k], atol=0.1)
    assert blob["q"].dtype == jnp.int8


@given(
    k=st.integers(2, 12),
    logd=st.integers(5, 12),
)
@settings(max_examples=15, deadline=None)
def test_property_kernel_vs_oracle(k, logd):
    d = 2 ** logd
    key = jax.random.PRNGKey(k * 31 + logd)
    stack = jax.random.normal(key, (k, d), jnp.float32)
    w = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(7), (k,)))
    np.testing.assert_allclose(
        ops.fedavg_agg(stack, w), ref.fedavg_agg_ref(stack, w), atol=1e-5
    )
    np.testing.assert_allclose(
        ops.cwmed(stack), ref.cwmed_ref(stack), atol=1e-6
    )
