"""Committee Consensus Mechanism (paper §III.B) + cost model (§V.A)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.consensus import CommitteeConsensus, consensus_cost
from repro.core.election import BY_SCORE, MULTI_FACTOR, RANDOM, elect


def make_consensus(scores_by_member, threshold=0.5):
    return CommitteeConsensus(
        list(scores_by_member.keys()),
        score_fn=lambda m, upd: scores_by_member[m](upd),
        accept_threshold=threshold,
    )


def test_median_scoring():
    cc = CommitteeConsensus(
        [0, 1, 2], score_fn=lambda m, u: {0: 0.2, 1: 0.9, 2: 0.4}[m]
    )
    rec = cc.validate(uploader=7, update="u")
    assert rec.median_score == pytest.approx(0.4)


def test_collusion_minority_cannot_inflate():
    # 2 of 5 malicious members give 1.0; median stays at honest level
    honest = 0.3
    cc = CommitteeConsensus(
        list(range(5)),
        score_fn=lambda m, u: 1.0 if m < 2 else honest,
    )
    rec = cc.validate(0, "u")
    assert rec.median_score == pytest.approx(honest)


def test_collusion_majority_wins():
    # the >M/2 condition of §IV.C: 3 of 5 colluding members control the median
    cc = CommitteeConsensus(
        list(range(5)), score_fn=lambda m, u: 1.0 if m < 3 else 0.0
    )
    assert cc.validate(0, "u").median_score == 1.0


def test_relative_threshold_rejects_degraded():
    scores = iter([0.8, 0.82, 0.1])
    cc = CommitteeConsensus(
        [0], score_fn=lambda m, u: next(scores), accept_threshold=0.5
    )
    assert cc.validate(0, "a").accepted
    assert cc.validate(1, "b").accepted
    assert not cc.validate(2, "c").accepted   # 0.1 < 0.5 * mean(0.8, 0.82)


def test_stats_count_pq():
    cc = CommitteeConsensus(list(range(4)), score_fn=lambda m, u: 0.5)
    for i in range(6):
        cc.validate(i, i)
    assert cc.stats.validations == 24  # P * Q


@given(P=st.integers(1, 500), Q=st.integers(1, 200))
@settings(max_examples=50, deadline=None)
def test_consensus_cost_always_cheaper(P, Q):
    ccm, broadcast = consensus_cost(P, Q)
    assert ccm == P * Q
    assert broadcast == (P + Q) ** 2
    assert ccm < broadcast  # P*Q < (P+Q)^2 always


# ---------------------------------------------------------------------------
# election (§IV.B)
# ---------------------------------------------------------------------------


def test_election_by_score_top():
    rng = np.random.default_rng(0)
    cand = {1: 0.5, 2: 0.9, 3: 0.7, 4: 0.1}
    assert elect(BY_SCORE, rng, cand, 2) == [2, 3]


def test_election_random_subset():
    rng = np.random.default_rng(0)
    cand = {i: 0.5 for i in range(10)}
    chosen = elect(RANDOM, rng, cand, 4)
    assert len(chosen) == 4 and set(chosen) <= set(cand)


def test_election_multi_factor_balances():
    rng = np.random.default_rng(0)
    cand = {1: 1.0, 2: 0.9, 3: 0.1}
    factors = {1: 0.0, 2: 1.0, 3: 1.0}
    chosen = elect(MULTI_FACTOR, rng, cand, 1, factors=factors,
                   score_weight=0.5)
    assert chosen == [2]  # best combined score+factor


def test_election_empty_candidates():
    rng = np.random.default_rng(0)
    assert elect(BY_SCORE, rng, {}, 3) == []


@given(
    n=st.integers(1, 30), m=st.integers(1, 10),
    method=st.sampled_from([RANDOM, BY_SCORE]),
)
@settings(max_examples=30, deadline=None)
def test_property_election_size_and_membership(n, m, method):
    rng = np.random.default_rng(0)
    cand = {i: float(i) / n for i in range(n)}
    chosen = elect(method, rng, cand, m)
    assert len(chosen) == min(m, n)
    assert len(set(chosen)) == len(chosen)
    assert set(chosen) <= set(cand)
