"""Property-based tests (hypothesis) for the chain's int8 update codec.

Covers ``Int8UpdateCodec`` (pytree <-> int8 blob) and the ``Chain``
update-block codec integration:

* encode -> decode roundtrip error is bounded by the per-tile quantization
  step (scale = max|x| / 127 per BLOCK_D tile, so |x - deq(q)| <= scale/2
  per element, up to f32 rounding);
* the block hash covers the ``encoded`` flag — an unauthenticated flip of
  the codec flag breaks verification;
* arbitrary pytree shapes/dtypes, including zero-length leaves and sizes
  that are not BLOCK_D-aligned.

Imports the ``_hypothesis_compat`` shim: with hypothesis absent the
property tests skip individually while the module still collects.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core.blockchain import Chain
from repro.kernels.ops import Int8UpdateCodec, dequantize, quantize
from repro.kernels.tiling import BLOCK_D

# leaf sizes deliberately straddle the tile boundary: empty, tiny,
# BLOCK_D-1 / BLOCK_D / BLOCK_D+1, and a multi-tile size
_SIZES = st.sampled_from([0, 1, 7, BLOCK_D - 1, BLOCK_D, BLOCK_D + 1, 5000])
_DTYPES = st.sampled_from([np.float32, np.float64, np.float16])


def _leaf(rng: np.random.Generator, size: int, dtype, scale: float):
    x = (rng.standard_normal(size) * scale).astype(dtype)
    # reshape some leaves to matrices: codecs must be shape-agnostic
    if size % 2 == 0 and size > 0:
        x = x.reshape(2, size // 2)
    return x


@st.composite
def _pytrees(draw):
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    n_leaves = draw(st.integers(1, 4))
    scale = draw(st.floats(1e-4, 1e3))
    leaves = {
        f"leaf{i}": _leaf(rng, draw(_SIZES), draw(_DTYPES), scale)
        for i in range(n_leaves)
    }
    return leaves


@given(tree=_pytrees())
@settings(max_examples=20, deadline=None)
def test_codec_roundtrip_error_bound(tree):
    codec = Int8UpdateCodec(tree)
    blob = codec.encode(tree)
    out = codec.decode(blob)
    for key, leaf in tree.items():
        dec = np.asarray(out[key], np.float64).reshape(-1)
        src = np.asarray(leaf, np.float64).reshape(-1)
        assert dec.shape == src.shape
        if src.size == 0:
            continue
        # per-tile bound: scale = amax_tile / 127 <= amax / 127, so the
        # quantization error is <= scale / 2 per element; the dtype term
        # absorbs the cast back to the leaf's dtype (f16: eps ~ 2^-11)
        amax = float(np.max(np.abs(src)))
        dtype_eps = 1e-3 if leaf.dtype == np.float16 else 1e-6
        bound = amax * (0.5 / 127.0 + dtype_eps) + 1e-7
        assert float(np.max(np.abs(dec - src))) <= bound


@given(tree=_pytrees())
@settings(max_examples=10, deadline=None)
def test_codec_blob_schema_and_chain_storage(tree):
    codec = Int8UpdateCodec(tree)
    blob = codec.encode(tree)
    assert set(blob) == {"q", "scales", "d"}
    q = np.asarray(blob["q"])
    assert q.dtype == np.int8
    assert q.shape[0] % BLOCK_D == 0 or q.shape[0] == 0
    assert int(blob["d"]) == codec.dim
    # a chain with this codec stores / decodes the blob transparently
    chain = Chain(1, update_codec=codec)
    chain.append_model({"w": np.zeros(3, np.float32)}, 0)
    chain.append_update(tree, uploader=7, score=0.5)
    assert chain.blocks[-1].encoded
    assert chain.verify()
    decoded = chain.update_payloads_at_round(0)[0]
    for key, leaf in tree.items():
        assert np.asarray(decoded[key]).shape == np.asarray(leaf).shape


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_block_hash_covers_encoded_flag(seed):
    rng = np.random.default_rng(seed)
    tree = {"w": rng.standard_normal(257).astype(np.float32)}
    codec = Int8UpdateCodec(tree)
    chain = Chain(1, update_codec=codec)
    chain.append_model({"w": np.zeros(3, np.float32)}, 0)
    blk = chain.append_update(tree, uploader=1, score=0.9)
    assert chain.verify()
    # flipping the codec flag without re-hashing must break the chain:
    # the flag decides how the stored blob is interpreted on read
    blk.encoded = not blk.encoded
    assert blk.compute_hash() != blk.hash
    assert not chain.verify()
    blk.encoded = not blk.encoded
    assert chain.verify()


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_hypothesis_is_exercised():
    """Meta-check so CI with hypothesis installed can't silently skip the
    property suite (locally, without hypothesis, this skips too)."""
    assert HAVE_HYPOTHESIS


def test_quantize_zero_length_vector():
    """Deterministic pin of the degenerate case (also hit by the
    hypothesis strategies): a zero-size flat vector roundtrips to a
    zero-size vector without launching a kernel."""
    q, s, d = quantize(jnp.zeros((0,), jnp.float32))
    assert q.shape == (0,) and s.shape == (0,) and d == 0
    out = dequantize(q, s, d)
    assert out.shape == (0,)


def test_codec_non_aligned_roundtrip_deterministic():
    """Deterministic (no-hypothesis) fallback for the roundtrip bound on a
    non-BLOCK_D-aligned, mixed-dtype tree — always runs, even where the
    property suite skips."""
    rng = np.random.default_rng(0)
    tree = {
        "a": rng.standard_normal(BLOCK_D + 3).astype(np.float32),
        "b": rng.standard_normal((2, 5)).astype(np.float16),
        "c": np.zeros((0,), np.float32),
    }
    codec = Int8UpdateCodec(tree)
    out = codec.decode(codec.encode(tree))
    for key, leaf in tree.items():
        src = np.asarray(leaf, np.float64)
        dec = np.asarray(out[key], np.float64)
        assert dec.shape == src.shape
        if src.size:
            amax = float(np.max(np.abs(src)))
            eps = 1e-3 if leaf.dtype == np.float16 else 1e-6
            assert (float(np.max(np.abs(dec - src)))
                    <= amax * (0.5 / 127.0 + eps) + 1e-7)
