"""Continuous-batching serve engine: oracle parity, scheduling behaviour,
and chain hot-swap correctness.

The load-bearing pins:
  * every request served by the slot engine decodes the SAME token ids as a
    single-request (batch-1) oracle run — in-flight batching must not change
    results;
  * a mid-trace hot swap completes without dropping in-flight requests, and
    requests served entirely under one params version stay oracle-exact for
    that version.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.launch.mesh import make_host_mesh
from repro.launch.shardings import ShardingPolicy
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import init_cache, init_model
from repro.models.cache import insert_slot_cache
from repro.models.transformer import Batch
from repro.serve import (
    ChainParamSource,
    CheckpointParamSource,
    FifoScheduler,
    Request,
    ServeEngine,
    SlotTable,
    VirtualClock,
    make_poisson_trace,
)

MAX_LEN = 48


@pytest.fixture(scope="module")
def cfg():
    return registry.get_config(
        "olmo-1b", d_model=64, num_units=2, num_heads=2, num_kv_heads=2,
        d_ff=128, vocab_size=512,
    )


@pytest.fixture(scope="module")
def params(cfg):
    return init_model(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def host_steps(cfg):
    mesh = make_host_mesh(1, 1)
    pol = ShardingPolicy(dp_axes=("data",), dp_sizes=(1,),
                         model_axis_size=1, fsdp=False)
    prefill = jax.jit(make_prefill_step(cfg, mesh, pol, max_len=MAX_LEN))
    decode = jax.jit(make_decode_step(cfg, mesh, pol, return_logits=False))
    return prefill, decode


def oracle_tokens(prefill, decode, params, prompt, max_new):
    """Batch-1 greedy generation: the single-request reference."""
    S = len(prompt)
    batch = Batch(
        tokens=jnp.asarray(prompt, jnp.int32)[None],
        positions=jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None], (1, S)),
    )
    logits, cache = prefill(params, batch)
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    out = [int(tok[0, 0])]
    pos = jnp.asarray([S], jnp.int32)
    for _ in range(max_new - 1):
        tok, cache = decode(params, tok, pos, cache, None)
        out.append(int(tok[0, 0]))
        pos = pos + 1
    return out


def mixed_trace(cfg, *, seed=1):
    rng = np.random.default_rng(seed)
    shapes = [(8, 5), (16, 12), (8, 1), (12, 3), (16, 8), (8, 6), (12, 10)]
    return [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, (s,)).astype(np.int32),
            max_new=g,
            arrival=float(i),
        )
        for i, (s, g) in enumerate(shapes)
    ]


# ----------------------------------------------------------------------------
# building blocks
# ----------------------------------------------------------------------------


def test_insert_slot_cache_writes_one_row(cfg):
    big = init_cache(cfg, 3, MAX_LEN, jnp.float32)
    small = init_cache(cfg, 1, MAX_LEN, jnp.float32)
    small = jax.tree.map(lambda x: jnp.full_like(x, 7), small)
    out = insert_slot_cache(big, small, jnp.asarray(1, jnp.int32))
    # unit leaves: stacked (num_units, B, ...) — batch axis 1
    for leaf_big, leaf_out in zip(jax.tree.leaves(big["units"]),
                                  jax.tree.leaves(out["units"])):
        np.testing.assert_array_equal(
            np.asarray(leaf_out[:, 1]), np.full_like(leaf_big[:, 1], 7))
        np.testing.assert_array_equal(
            np.asarray(leaf_out[:, 0]), np.asarray(leaf_big[:, 0]))
        np.testing.assert_array_equal(
            np.asarray(leaf_out[:, 2]), np.asarray(leaf_big[:, 2]))
    # tail leaves: plain (B, ...) — batch axis 0
    for leaf_big, leaf_out in zip(jax.tree.leaves(big["tail"]),
                                  jax.tree.leaves(out["tail"])):
        np.testing.assert_array_equal(
            np.asarray(leaf_out[1]), np.full_like(leaf_big[1], 7))
        np.testing.assert_array_equal(
            np.asarray(leaf_out[0]), np.asarray(leaf_big[0]))


def test_decode_step_logits_optin(cfg, params):
    mesh = make_host_mesh(1, 1)
    pol = ShardingPolicy(dp_axes=("data",), dp_sizes=(1,),
                         model_axis_size=1, fsdp=False)
    with_logits = jax.jit(make_decode_step(cfg, mesh, pol))
    no_logits = jax.jit(make_decode_step(cfg, mesh, pol, return_logits=False))
    cache = init_cache(cfg, 2, MAX_LEN, jnp.float32)
    toks = jnp.asarray([[3], [5]], jnp.int32)
    pos = jnp.asarray([4, 9], jnp.int32)
    t3, logits, _ = with_logits(params, toks, pos, cache, None)
    out = no_logits(params, toks, pos, cache, None)
    assert len(out) == 2, "logits must be dropped when opted out"
    t2, _ = out
    np.testing.assert_array_equal(np.asarray(t2), np.asarray(t3))
    assert logits.shape == (2, 1, cfg.vocab_size)


def test_scheduler_static_barrier():
    reqs = [Request(rid=i, prompt=np.zeros((4,), np.int32), max_new=2,
                    arrival=0.0) for i in range(4)]
    table = SlotTable(2)
    sched = FifoScheduler(reqs, policy="static")
    first = sched.admissions(table, 0.0)
    assert [b for b, _ in first] == [0, 1]
    for b, r in first:
        table.occupy(b, r.rid, r.max_new)
    table.release(0)
    # one slot free, one busy: static admits nothing until the batch drains
    assert sched.admissions(table, 0.0) == []
    table.release(1)
    assert len(sched.admissions(table, 0.0)) == 2


def test_scheduler_continuous_fills_any_free_slot():
    reqs = [Request(rid=i, prompt=np.zeros((4,), np.int32), max_new=2,
                    arrival=float(i)) for i in range(3)]
    table = SlotTable(2)
    sched = FifoScheduler(reqs, policy="continuous")
    got = sched.admissions(table, 0.0)
    assert len(got) == 1                      # only rid 0 has arrived
    table.occupy(got[0][0], 0, 2)
    got = sched.admissions(table, 5.0)        # rids 1,2 arrived; 1 slot free
    assert len(got) == 1 and got[0][1].rid == 1
    assert sched.queued == 1


def test_poisson_trace_shapes():
    trace = make_poisson_trace(num_requests=32, rate=10.0,
                               prompt_lens=(4, 8), gen_lens=(2, 6),
                               vocab_size=100, seed=3)
    assert len(trace) == 32
    arrivals = [r.arrival for r in trace]
    assert arrivals == sorted(arrivals) and arrivals[0] > 0
    assert all(r.prompt_len in (4, 8) and r.max_new in (2, 6) for r in trace)
    assert all(0 <= r.prompt.min() and r.prompt.max() < 100 for r in trace)


def test_engine_rejects_oversized_request(cfg, params):
    eng = ServeEngine(cfg, params, num_slots=2, max_len=16)
    bad = [Request(rid=0, prompt=np.zeros((12,), np.int32), max_new=8)]
    with pytest.raises(ValueError, match="exceeds max_len"):
        eng.run(bad, clock=VirtualClock())


# ----------------------------------------------------------------------------
# oracle parity
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["continuous", "static"])
def test_engine_matches_single_request_oracle(cfg, params, host_steps, policy):
    prefill, decode = host_steps
    trace = mixed_trace(cfg)
    eng = ServeEngine(cfg, params, num_slots=3, max_len=MAX_LEN)
    rep = eng.run(trace, policy=policy, clock=VirtualClock())
    assert rep.policy == policy
    for res, req in zip(rep.results, trace):
        assert len(res.tokens) == req.max_new
        want = oracle_tokens(prefill, decode, params, req.prompt, req.max_new)
        assert res.tokens == want, (policy, res.rid)
    m = rep.metrics()
    assert m["requests"] == len(trace)
    assert m["generated_tokens"] == sum(r.max_new for r in trace)
    assert 0.0 < rep.occupancy <= 1.0


def test_continuous_frees_slots_static_stalls(cfg, params):
    """One long request pins a slot; short requests keep arriving.  The
    continuous engine serves them through the freed slot while the long one
    decodes; the static barrier parks them until the whole batch drains."""
    rng = np.random.default_rng(0)

    def mk(rid, gen, arrival):
        return Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32),
            max_new=gen, arrival=arrival,
        )

    trace = [mk(0, 30, 0.0), mk(1, 4, 0.0), mk(2, 4, 1.0), mk(3, 4, 2.0)]
    eng = ServeEngine(cfg, params, num_slots=2, max_len=MAX_LEN)
    cont = eng.run(trace, policy="continuous", clock=VirtualClock()).by_rid()
    stat = eng.run(trace, policy="static", clock=VirtualClock()).by_rid()
    # static: rid 1 finished early but its slot stays barred — rids 2/3 are
    # only admitted once the 30-token request drains the batch
    assert stat[2].admitted > stat[1].finished
    # continuous: rid 2 rides the slot rid 1 freed, long before that
    assert cont[2].admitted < stat[2].admitted
    assert cont[3].first_token < stat[3].first_token
    assert cont[3].finished < stat[3].finished


# ----------------------------------------------------------------------------
# hot swap
# ----------------------------------------------------------------------------


def test_chain_hot_swap_keeps_untouched_slots_oracle_exact(
        cfg, params, host_steps):
    from repro.core.blockchain import Chain

    prefill, decode = host_steps
    params1 = init_model(jax.random.PRNGKey(9), cfg)
    chain = Chain(k_updates_per_round=1)
    chain.append_model(params, 0)

    rng = np.random.default_rng(4)

    def mk(rid, gen, arrival):
        return Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32),
            max_new=gen, arrival=arrival,
        )

    # rid 0 finishes before the swap; rid 1 spans it; rid 2 starts after
    trace = [mk(0, 3, 0.0), mk(1, 24, 0.0), mk(2, 5, 10.0)]
    swap_tick = 6
    committed = []

    def commit(tick):
        if tick == swap_tick and not committed:
            chain.append_update(jax.tree.map(np.zeros_like, params),
                                uploader=0, score=1.0)
            chain.append_model(params1, 1)
            committed.append(tick)

    eng = ServeEngine(cfg, params, num_slots=2, max_len=MAX_LEN,
                      param_source=ChainParamSource(chain))
    rep = eng.run(trace, policy="continuous", clock=VirtualClock(),
                  on_tick=commit)
    assert len(rep.swaps) == 1 and rep.swaps[0]["round"] == 1
    by = rep.by_rid()

    # nothing dropped or truncated across the swap
    for req in trace:
        assert len(by[req.rid].tokens) == req.max_new

    # pre-swap request: bit-identical to the params-v0 oracle
    assert by[0].version_admitted == 0 and by[0].version_finished == 0
    assert not by[0].spans_swap
    assert by[0].tokens == oracle_tokens(
        prefill, decode, params, trace[0].prompt, 3)

    # post-swap request: bit-identical to the params-v1 oracle
    assert by[2].version_admitted == 1 and by[2].version_finished == 1
    assert by[2].tokens == oracle_tokens(
        prefill, decode, params1, trace[2].prompt, 5)

    # the spanning request crossed versions, met its budget, and its
    # pre-swap prefix is v0-oracle-exact — the swap changes params only,
    # never the in-flight KV state
    assert by[1].spans_swap
    v0 = oracle_tokens(prefill, decode, params, trace[1].prompt, 24)
    assert by[1].tokens[:4] == v0[:4]


def test_checkpoint_param_source_roundtrip(cfg, params, tmp_path):
    from repro.checkpoint import save_pytree
    from repro.kernels.ops import Int8UpdateCodec
    from repro.serve.params import checkpoint_name

    src = CheckpointParamSource(str(tmp_path), start_round=0)
    assert src.poll() is None

    params1 = init_model(jax.random.PRNGKey(3), cfg)
    save_pytree(str(tmp_path / checkpoint_name(1)), params1)
    ver, got = src.poll()
    assert ver == 1
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(params1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert src.poll() is None                 # same round: no re-swap

    # int8-codec chain blob snapshot: decoded through the codec
    codec = Int8UpdateCodec(params)
    blob = codec.encode(params1)
    save_pytree(str(tmp_path / checkpoint_name(2)), blob)
    src2 = CheckpointParamSource(str(tmp_path), codec=codec, start_round=1)
    ver, got = src2.poll()
    assert ver == 2
    want = codec.decode(blob)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
