"""Optimizer, schedules, checkpoint, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import load_pytree, save_pytree
from repro.data import dirichlet_partition, leaf_style_partition, make_femnist_like
from repro.data.lm_synthetic import MarkovLM
from repro.optim import adamw, constant, cosine_decay, linear_warmup_cosine, sgd


def quad_loss(p):
    return jnp.sum((p["x"] - 3.0) ** 2) + jnp.sum((p["y"] + 1.0) ** 2)


@pytest.mark.parametrize("opt", [
    sgd(0.1), sgd(0.05, momentum=0.9), adamw(0.1),
    adamw(0.1, moment_dtype=jnp.bfloat16),
])
def test_optimizers_converge(opt):
    params = {"x": jnp.zeros((3,)), "y": jnp.ones((2,))}
    state = opt.init(params)
    for step in range(300):
        g = jax.grad(quad_loss)(params)
        params, state = opt.update(g, state, params, step)
    assert quad_loss(params) < 1e-2


def test_adamw_grad_clip():
    opt = adamw(0.1, grad_clip_norm=1.0)
    params = {"x": jnp.zeros((3,))}
    state = opt.init(params)
    g = {"x": jnp.full((3,), 1e6)}
    new, _ = opt.update(g, state, params, 0)
    assert float(jnp.abs(new["x"]).max()) < 1.0


def test_schedules():
    assert float(constant(0.1)(5)) == pytest.approx(0.1)
    cd = cosine_decay(1.0, 100, final_frac=0.1)
    assert float(cd(0)) == pytest.approx(1.0)
    assert float(cd(100)) == pytest.approx(0.1, abs=1e-6)
    wc = linear_warmup_cosine(1.0, 10, 100)
    assert float(wc(5)) == pytest.approx(0.5)
    assert float(wc(10)) == pytest.approx(1.0, rel=1e-2)


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((2,), jnp.bfloat16), "d": None},
        "e": (jnp.zeros((1,)), jnp.array(3, jnp.int32)),
    }
    path = str(tmp_path / "ckpt.msgpack")
    save_pytree(path, tree)
    out = load_pytree(path)
    assert out["b"]["d"] is None
    np.testing.assert_array_equal(out["a"], tree["a"])
    assert out["b"]["c"].dtype == jnp.bfloat16
    assert isinstance(out["e"], tuple)
    out2 = load_pytree(path, like=tree)
    np.testing.assert_array_equal(out2["e"][1], 3)


def test_femnist_like_stats():
    ds = make_femnist_like(num_clients=30, mean_samples=50, test_size=300,
                           seed=0, classes_per_client=6)
    assert ds.num_clients == 30
    assert ds.test_images.shape == (300, 28, 28, 1)
    # non-IID: each client sees few classes
    for lbl in ds.client_labels[:10]:
        assert len(np.unique(lbl)) <= 6
    # unbalanced sizes
    sizes = ds.client_sizes()
    assert sizes.min() >= 8 and sizes.std() > 5
    merged_x, merged_y = ds.merged_train()
    assert len(merged_x) == sizes.sum()


@given(alpha=st.floats(0.1, 10.0), clients=st.integers(2, 12))
@settings(max_examples=10, deadline=None)
def test_property_dirichlet_partition_covers(alpha, clients):
    labels = np.repeat(np.arange(5), 40)
    parts = dirichlet_partition(labels, clients, alpha, seed=1)
    all_idx = np.concatenate(parts)
    assert len(all_idx) == len(labels)
    assert len(np.unique(all_idx)) == len(labels)  # partition, no overlap


def test_leaf_partition_class_limit():
    labels = np.repeat(np.arange(10), 30)
    parts = leaf_style_partition(labels, 6, classes_per_client=3, seed=0)
    for p in parts:
        assert len(np.unique(labels[p])) <= 3


def test_markov_lm_learnable_structure():
    lm = MarkovLM(128, branching=4, seed=0)
    rng = np.random.default_rng(0)
    toks, tgts = lm.batch(rng, 4, 64)
    assert toks.shape == (4, 64)
    # every target is a legal successor of its token
    legal = 0
    for b in range(4):
        for t in range(64):
            legal += tgts[b, t] in lm.succ[toks[b, t]]
    assert legal == 4 * 64
    assert lm.entropy() < np.log(128)
