"""Multi-device tests (EP-MoE equivalence, sharding specs, committee-weighted
train step) — run in a subprocess with 8 fake host devices so the rest of the
suite keeps seeing the single real CPU device."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_moe_expert_parallel_equals_dense():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_host_mesh
        from repro.models.config import ModelConfig, moe_unit
        from repro.models.moe import (MoEShardingCtx, init_moe, moe_dense,
                                      moe_expert_parallel)
        mesh = make_host_mesh(2, 4)
        cfg = ModelConfig(name="t", arch_type="moe", d_model=32, vocab_size=97,
                          unit=moe_unit(1), num_units=1, num_heads=4,
                          num_kv_heads=4, d_ff=64, num_experts=8,
                          num_experts_per_tok=2, moe_d_ff=48,
                          moe_capacity_factor=8.0)
        p = init_moe(jax.random.PRNGKey(1), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(2), (4, 8, 32))
        ref, _ = moe_dense(p, x, cfg)
        ctx = MoEShardingCtx(mesh=mesh, dp_axes=("data",), model_axis="model")
        out, _ = jax.jit(lambda p_, x_: moe_expert_parallel(p_, x_, cfg, ctx))(p, x)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out), atol=1e-4)
        # virtual experts: E=2 < M=4
        cfg2 = cfg.replace(num_experts=2, num_experts_per_tok=1)
        p2 = init_moe(jax.random.PRNGKey(3), cfg2, jnp.float32, virtual_r=2)
        ref2, _ = moe_dense(p2, x, cfg2)
        out2, _ = jax.jit(lambda p_, x_: moe_expert_parallel(p_, x_, cfg2, ctx))(p2, x)
        np.testing.assert_allclose(np.asarray(ref2), np.asarray(out2), atol=1e-4)
        print("EP OK")
    """)


def test_sharded_train_step_runs_and_matches_single_device():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import registry
        from repro.launch.mesh import make_host_mesh
        from repro.launch.shardings import (ShardingPolicy, batch_pspecs,
                                            named, param_pspecs)
        from repro.launch.steps import TrainState, make_train_step
        from repro.models import init_model
        from repro.models.frontends import lm_batch
        from repro.optim import sgd

        cfg = registry.smoke_config("olmo-1b")
        mesh = make_host_mesh(2, 4)
        pol = ShardingPolicy(dp_axes=("data",), dp_sizes=(2,), model_axis_size=4)
        params = init_model(jax.random.PRNGKey(0), cfg)
        opt = sgd(0.1)
        batch = lm_batch(jax.random.PRNGKey(1), cfg, 4, 16)

        step = make_train_step(cfg, opt, mesh, pol, mode="standard")
        pspecs = param_pspecs(cfg, params, pol)
        st_sh = TrainState(named(mesh, pspecs), {},
                           jax.NamedSharding(mesh, jax.sharding.PartitionSpec()))
        jstep = jax.jit(step, in_shardings=(st_sh, named(mesh,
                        batch_pspecs(cfg, pol, batch_sharded=True)), None))
        state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
        new_state, m = jstep(state, batch, None)
        sharded_loss = float(m["loss"])

        # single-device reference
        mesh1 = make_host_mesh(1, 1)
        pol1 = ShardingPolicy(dp_axes=("data",), model_axis_size=1, fsdp=False)
        step1 = make_train_step(cfg, opt, mesh1, pol1, mode="standard")
        _, m1 = jax.jit(step1)(state, batch, None)
        assert abs(sharded_loss - float(m1["loss"])) < 1e-3, (sharded_loss, float(m1["loss"]))
        print("TRAIN STEP OK", sharded_loss)
    """)


def test_bflc_mode_train_step_downweights_poisoned_cohort():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import registry
        from repro.launch.mesh import make_host_mesh
        from repro.launch.shardings import ShardingPolicy
        from repro.launch.steps import TrainState, make_train_step, bflc_loss, make_moe_ctx
        from repro.models import init_model
        from repro.models.frontends import lm_batch
        from repro.optim import sgd

        cfg = registry.smoke_config("olmo-1b")
        mesh = make_host_mesh(2, 4)
        pol = ShardingPolicy(dp_axes=("data",), dp_sizes=(2,), model_axis_size=4)
        params = init_model(jax.random.PRNGKey(0), cfg)
        batch = lm_batch(jax.random.PRNGKey(1), cfg, 8, 16)
        # poison cohort 0: random targets -> anomalous cohort loss
        tgts = batch.targets.at[0:2].set(
            jax.random.randint(jax.random.PRNGKey(9), (2, 16), 0, cfg.vocab_size))
        batch = batch._replace(targets=tgts)
        val = lm_batch(jax.random.PRNGKey(2), cfg, 4, 16)
        ctx = make_moe_ctx(cfg, mesh, pol, batch_sharded=True)
        total, ce = bflc_loss(params, cfg, batch, val, ctx,
                              num_cohorts=4, committee_size=4)
        assert np.isfinite(float(total))
        print("BFLC STEP OK", float(total))
    """)


def test_decode_step_sharded():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import registry
        from repro.launch.mesh import make_host_mesh
        from repro.launch.shardings import (ShardingPolicy, cache_pspecs, named)
        from repro.launch.steps import make_decode_step, make_prefill_step
        from repro.models import init_cache, init_model
        from repro.models.frontends import lm_batch

        cfg = registry.smoke_config("mixtral-8x7b")
        mesh = make_host_mesh(2, 4)
        pol = ShardingPolicy(dp_axes=("data",), dp_sizes=(2,), model_axis_size=4, fsdp=False)
        params = init_model(jax.random.PRNGKey(0), cfg, virtual_r=1)
        B, S = 4, 16
        batch = lm_batch(jax.random.PRNGKey(1), cfg, B, S)
        prefill = jax.jit(make_prefill_step(cfg, mesh, pol, max_len=S + 4))
        logits, cache = prefill(params, batch)
        decode = jax.jit(make_decode_step(cfg, mesh, pol))
        tok = jnp.ones((B, 1), jnp.int32)
        pos = jnp.full((B,), S, jnp.int32)
        nt, lg, cache2 = decode(params, tok, pos, cache, None)
        assert nt.shape == (B, 1)
        assert not np.isnan(np.asarray(lg, np.float32)).any()
        print("DECODE OK")
    """)
