import os

# Tests run on the single real CPU device (the dry-run sets its own
# XLA_FLAGS in a separate process; never set 512 fake devices globally).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
