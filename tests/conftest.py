import os
import sys

# src on sys.path before the bootstrap import below — deterministic,
# regardless of whether pytest.ini's pythonpath took effect yet
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.hostdevices import force_host_devices

# The suite runs on CPU with 8 forced host devices so the sharded round
# engine (repro.fl.sharded) is exercised for real — shard_map over 1/2/8
# devices — without a TPU.  Flags land before jax initializes its backend;
# an externally-provided force_host flag wins.  The dry-run still sets its
# own XLA_FLAGS in a separate process.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
force_host_devices()

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def round_mesh():
    """Factory: 1-D ("data",) mesh over the first n forced CPU devices.

    ``round_mesh(8)`` etc. — skips (rather than errors) when the process
    has fewer devices than requested, so the suite degrades gracefully if
    run without the forced-device flag."""
    import jax

    from repro.launch.mesh import make_round_mesh

    def make(n: int):
        if len(jax.devices()) < n:
            pytest.skip(f"needs {n} devices, have {len(jax.devices())}")
        return make_round_mesh(n)

    return make
