"""Fused quantized aggregation engine: parity vs f32 jnp oracles.

Tolerance note: the fused path reads int8 inputs, so outputs can differ
from the f32 oracle only through quantization error — bounded by the
per-tile scale (half an int8 step per element; 2*scale is a loose cover
for the reductions and the optional output re-quantization step).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import kernels
from repro.kernels import ops, ref

KS = (3, 5, 8)
DS = (2048, 4096)                 # block-aligned
DS_RAGGED = (100, 2049, 5000)     # exercise the padding edges
METHODS = ("fedavg", "cwmed", "trimmed_mean")


def _stack_and_weights(K, D, seed=0):
    stack = jax.random.normal(jax.random.PRNGKey(seed), (K, D), jnp.float32) * 3
    w = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(seed + 1), (K,)))
    return stack, w


# ----------------------------------------------------------------------
# new f32 trimmed-mean kernel
# ----------------------------------------------------------------------
@pytest.mark.parametrize("K", KS + (4, 17))
@pytest.mark.parametrize("D", DS + DS_RAGGED)
def test_trimmed_mean_matches_ref(K, D):
    stack, _ = _stack_and_weights(K, D, seed=K + D)
    trim = (K - 1) // 2
    np.testing.assert_allclose(
        ops.trimmed_mean(stack, trim=trim),
        ref.trimmed_mean_ref(stack, trim),
        atol=1e-5,
    )


def test_trimmed_mean_trim_zero_is_mean():
    stack, _ = _stack_and_weights(4, 2048)
    np.testing.assert_allclose(
        ops.trimmed_mean(stack, trim=0), stack.mean(axis=0), atol=1e-5
    )


def test_trimmed_mean_rejects_bad_trim():
    stack, _ = _stack_and_weights(4, 2048)
    with pytest.raises(ValueError):
        ops.trimmed_mean(stack, trim=2)


def test_aggregate_dispatch_rejects_unknown_method():
    stack, w = _stack_and_weights(4, 2048)
    with pytest.raises(ValueError):
        ops.aggregate(stack, "krum", weights=w)


# ----------------------------------------------------------------------
# stack quantizer (the round codec)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("D", (2048, 5000))
def test_quantize_stack_matches_per_row_ref(D):
    stack, _ = _stack_and_weights(6, D)
    q, s, d = ops.quantize_stack(stack)
    assert d == D and q.dtype == jnp.int8
    assert q.shape[1] == kernels.padded_dim(D)
    for i in range(stack.shape[0]):
        qi, si, _ = ops.quantize(stack[i])
        np.testing.assert_array_equal(q[i], qi)
        np.testing.assert_allclose(s[i], si, rtol=1e-6)


# ----------------------------------------------------------------------
# fused int8 path vs f32 oracle (atol <= 2*scale)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("K", KS)
@pytest.mark.parametrize("D", DS + DS_RAGGED)
def test_fused_matches_f32_oracle(method, K, D):
    stack, w = _stack_and_weights(K, D, seed=K * 131 + D)
    q, s, d = ops.quantize_stack(stack)
    out = ops.aggregate_quantized(q, s, d, method=method, weights=w)
    assert out.shape == (D,)
    if method == "fedavg":
        oracle = ref.fedavg_agg_ref(stack, w / w.sum())
    elif method == "cwmed":
        oracle = ref.cwmed_ref(stack)
    else:
        oracle = ref.trimmed_mean_ref(stack, 1)
    tol = 2.0 * float(s.max())
    np.testing.assert_allclose(out, oracle, atol=tol)


@pytest.mark.parametrize("method", METHODS)
def test_fused_matches_staged_dequant_exactly(method):
    # vs the *staged* oracle (dequantize-then-reduce): identical inputs, so
    # agreement is to float tolerance, not quantization tolerance
    K, D = 5, 5000
    stack, w = _stack_and_weights(K, D)
    q, s, d = ops.quantize_stack(stack)
    out = ops.aggregate_quantized(q, s, d, method=method, weights=w)
    oracle = ref.fused_agg_ref(q, s, w / w.sum(), method=method, trim=1)[:d]
    np.testing.assert_allclose(out, oracle, atol=1e-5)


@pytest.mark.parametrize("K", KS)
@pytest.mark.parametrize("D", DS_RAGGED)
def test_fused_candidates_matches_staged(K, D):
    # the validation-side fused pass (score-from-int8): one read of the
    # int8 rows with the base-params delta applied in-register must equal
    # the staged dequantize-then-add pipeline to float tolerance (XLA may
    # contract the in-register base + q*scale into an fma, so the staged
    # path's intermediate f32 rounding is the only permitted divergence)
    stack, _ = _stack_and_weights(K, D, seed=K)
    base = _stack_and_weights(1, D, seed=K + 7)[0][0]
    q, s, d = ops.quantize_stack(stack)
    fused = ops.candidates_from_quantized(base, q, s, d)
    staged = jnp.stack([ops.dequantize(q[i], s[i], d) for i in range(K)])
    staged = staged + base[None, :d]
    assert fused.shape == (K, D)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(staged),
                               atol=1e-5)


@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("D", (2048, 5000))
def test_fused_quantize_out_roundtrip_bound(method, D):
    # quantize -> fused aggregate -> requantize -> dequantize stays within
    # input-quantization + output-quantization error of the f32 oracle
    K = 5
    stack, w = _stack_and_weights(K, D, seed=D)
    q, s, d = ops.quantize_stack(stack)
    qo, so, do = ops.aggregate_quantized(
        q, s, d, method=method, weights=w, quantize_out=True
    )
    assert qo.dtype == jnp.int8 and do == D
    out = ops.dequantize(qo, so, do)
    oracle = ref.fused_agg_ref(q, s, w / w.sum(), method=method, trim=1)[:d]
    tol = 2.0 * float(jnp.maximum(s.max(), so.max()))
    np.testing.assert_allclose(out, oracle, atol=tol)


def test_fused_unweighted_defaults_to_uniform():
    K, D = 4, 2048
    stack, _ = _stack_and_weights(K, D)
    q, s, d = ops.quantize_stack(stack)
    out = ops.aggregate_quantized(q, s, d, method="fedavg")
    uniform = jnp.full((K,), 1.0 / K)
    np.testing.assert_allclose(
        out, ref.fused_agg_ref(q, s, uniform, method="fedavg"), atol=1e-5
    )


# ----------------------------------------------------------------------
# pytree-level quantized aggregation (the runtime's chain path)
# ----------------------------------------------------------------------
def test_aggregate_quantized_blobs_matches_f32_pytrees():
    from repro.core.aggregation import (
        aggregate_pytrees,
        aggregate_quantized_blobs,
        flatten_updates,
    )

    key = jax.random.PRNGKey(0)
    ups = [
        {"w": jax.random.normal(jax.random.fold_in(key, i), (30, 40)),
         "b": jax.random.normal(jax.random.fold_in(key, 50 + i), (7,))}
        for i in range(5)
    ]
    stack, unravel = flatten_updates(ups)
    q, s, d = ops.quantize_stack(stack)
    blobs = [{"q": q[i], "scales": s[i], "d": d} for i in range(5)]
    weights = [0.5, 1.0, 2.0, 1.0, 0.5]
    got = aggregate_quantized_blobs(blobs, unravel, "fedavg", weights)
    want = aggregate_pytrees(ups, "fedavg", weights)
    tol = 2.0 * float(s.max())
    for k in ("w", "b"):
        np.testing.assert_allclose(got[k], want[k], atol=tol)
