"""Model-zoo behaviour: decode==forward consistency, chunked==dense attention,
flash gradients, M-RoPE, MoE dense path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (
    Batch, LayerSpec, ModelConfig, decode_step, forward, init_cache,
    init_model, prefill,
)
from repro.models.config import MLP_RWKV, dense_unit, moe_unit
from repro.models.frontends import hubert_batch, lm_batch, vlm_batch

KEY = jax.random.PRNGKey(0)


def tiny_dense(**kw):
    base = dict(
        name="t", arch_type="dense", d_model=64, vocab_size=97,
        unit=dense_unit(1), num_units=2, num_heads=4, num_kv_heads=2,
        d_ff=128,
    )
    base.update(kw)
    return ModelConfig(**base)


def decode_matches_forward(cfg, params, S=16, atol=5e-3):
    b = lm_batch(KEY, cfg, 2, S)
    _, cache = prefill(params, cfg, b, max_len=S + 8)
    tok = jnp.full((2, 1), 3, jnp.int32)
    pos = jnp.full((2,), S, jnp.int32)
    lg_dec, _ = decode_step(params, cfg, tok, pos, cache)
    ext = jnp.concatenate([b.tokens, tok], axis=1)
    b_ext = lm_batch(KEY, cfg, 2, S + 1)._replace(tokens=ext)
    lg_full, _ = forward(params, cfg, b_ext)
    return float(jnp.abs(lg_full[:, -1:] - lg_dec).max()) < atol


def test_dense_decode_consistency():
    cfg = tiny_dense()
    params = init_model(KEY, cfg)
    assert decode_matches_forward(cfg, params)


def test_swa_ring_buffer_decode():
    cfg = tiny_dense(unit=dense_unit(1, mixer="attn_swa"), sliding_window=8)
    params = init_model(KEY, cfg)
    assert decode_matches_forward(cfg, params, S=24)


def test_rwkv_decode_consistency():
    cfg = ModelConfig(
        name="r", arch_type="ssm", d_model=64, vocab_size=97,
        unit=(LayerSpec(mixer="rwkv6", mlp=MLP_RWKV),), num_units=2,
        d_ff=128, rwkv_head_dim=16, rwkv_lora_mix=8, rwkv_lora_decay=8,
    )
    params = init_model(KEY, cfg)
    assert decode_matches_forward(cfg, params, atol=5e-2)


def test_hybrid_decode_consistency():
    cfg = ModelConfig(
        name="j", arch_type="hybrid", d_model=64, vocab_size=97,
        unit=(LayerSpec(mixer="attn", mlp="dense"),
              LayerSpec(mixer="mamba", mlp="moe")),
        num_units=2, num_heads=4, num_kv_heads=2, d_ff=128,
        num_experts=4, num_experts_per_tok=2, mamba_d_state=8,
    )
    params = init_model(KEY, cfg)
    assert decode_matches_forward(cfg, params, atol=5e-2)


def test_chunked_attention_equals_dense():
    from repro.models import attention as attn

    cfg = tiny_dense()
    params = init_model(KEY, cfg)
    b = lm_batch(KEY, cfg, 2, 2048)
    ref, _ = forward(params, cfg, b)
    old = attn.DENSE_MAX
    try:
        attn.DENSE_MAX = 256
        out, _ = forward(params, cfg, b)
    finally:
        attn.DENSE_MAX = old
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out), atol=5e-4)


def test_flash_gradients_match_dense():
    from repro.models.attention import _dense_attention, _pair_mask
    from repro.models.flash import flash_attention

    B, S, H, Kv, Dh = 2, 1024, 4, 2, 16
    q = jax.random.normal(KEY, (B, S, H, Dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Kv, Dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Kv, Dh))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    ct = jax.random.normal(jax.random.PRNGKey(3), (B, S, H, Dh))
    for causal, window in ((True, 0), (True, 64), (False, 0)):
        mask = _pair_mask(pos, pos, causal=causal, window=window)
        g_ref = jax.grad(
            lambda *xs: (_dense_attention(*xs, mask, 0.0) * ct).sum(),
            argnums=(0, 1, 2),
        )(q, k, v)
        g_fl = jax.grad(
            lambda *xs: (flash_attention(*xs, pos, pos, causal, window) * ct).sum(),
            argnums=(0, 1, 2),
        )(q, k, v)
        for a, b in zip(g_ref, g_fl):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_mrope_reduces_to_rope_on_text():
    cfg = tiny_dense(rope="mrope", mrope_sections=(2, 3, 3), frontend="vision",
                     arch_type="vlm")
    params = init_model(KEY, cfg)
    bv = vlm_batch(KEY, cfg, 2, 32)
    lv, _ = forward(params, cfg, bv)
    cfg_std = cfg.replace(rope="standard")
    ls, _ = forward(params, cfg_std, bv._replace(positions=bv.positions[0]))
    np.testing.assert_allclose(np.asarray(lv), np.asarray(ls), atol=1e-5)


def test_mrope_image_positions_change_output():
    cfg = tiny_dense(rope="mrope", mrope_sections=(2, 3, 3), frontend="vision",
                     arch_type="vlm")
    params = init_model(KEY, cfg)
    b_img = vlm_batch(KEY, cfg, 2, 32, image_patches=12, grid=(3, 4))
    b_txt = b_img._replace(
        positions=jnp.broadcast_to(
            jnp.arange(32, dtype=jnp.int32)[None, None], (3, 2, 32)
        )
    )
    l_img, _ = forward(params, cfg, b_img)
    l_txt, _ = forward(params, cfg, b_txt)
    assert float(jnp.abs(l_img - l_txt).max()) > 1e-4


def test_encoder_masked_prediction():
    cfg = tiny_dense(causal=False, norm="layernorm", act="gelu", rope="none",
                     frontend="audio", arch_type="audio", vocab_size=54,
                     num_kv_heads=4)
    params = init_model(KEY, cfg)
    b = hubert_batch(KEY, cfg, 2, 32)
    logits, _ = forward(params, cfg, b)
    assert logits.shape == (2, 32, 54)
    assert not jnp.isnan(logits).any()
    # bidirectional: future context must influence earlier positions.
    # Perturb row 0's LAST unmasked frame — masked frames are replaced by
    # mask_emb in embed_inputs, so perturbing one of those (e.g. blindly
    # using frame -1) never reaches the model at all.
    col = int(jnp.where(~b.embed_mask[0], jnp.arange(32), -1).max())
    assert col > 0, "fixed-seed batch left row 0 fully masked"
    b2 = b._replace(embeds=b.embeds.at[0, col].add(10.0))
    logits2, _ = forward(params, cfg, b2)
    assert float(jnp.abs(logits2[0, 0] - logits[0, 0]).max()) > 1e-5


def test_moe_dense_topk_selectivity():
    from repro.models.moe import init_moe, moe_dense, route

    cfg = tiny_dense(arch_type="moe", unit=moe_unit(1), num_experts=4,
                     num_experts_per_tok=2, moe_d_ff=32)
    p = init_moe(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (64, cfg.d_model))
    w, ids, aux = route(p, x, cfg)
    assert w.shape == (64, 2) and float(aux) > 0
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)
    assert int(ids.max()) < 4
    out, _ = moe_dense(p, x.reshape(1, 64, -1), cfg)
    assert not jnp.isnan(out).any()


def test_gqa_head_grouping():
    """GQA output must change when kv heads differ; sanity of reshape."""
    cfg_full = tiny_dense(num_kv_heads=4)
    cfg_gqa = tiny_dense(num_kv_heads=2)
    p_full = init_model(KEY, cfg_full)
    b = lm_batch(KEY, cfg_full, 2, 16)
    out_full, _ = forward(p_full, cfg_full, b)
    assert out_full.shape == (2, 16, 97)
    p_gqa = init_model(KEY, cfg_gqa)
    out_gqa, _ = forward(p_gqa, cfg_gqa, b)
    assert out_gqa.shape == (2, 16, 97)
