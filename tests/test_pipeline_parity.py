"""Refactor parity: the stage pipeline reproduces the pre-refactor
monolithic round loop bit-for-bit.

``LegacyBFLCRuntime`` overrides ``run_round`` with a verbatim copy of the
monolith this PR decomposed (same ``__init__`` via inheritance, so both
start from the same RNG stream and genesis block).  A fixed-seed run
through the new pipeline must produce an identical chain — heights,
block hashes, packed uploader ids — and identical ``RoundLog``s, for
both the f32 and ``quantize_chain=True`` paths.  ``LegacyFLTrainer``
does the same for the Basic FL / CwMed baseline.
"""
import jax
import numpy as np
import pytest

from repro.core.aggregation import (
    aggregate_pytrees,
    apply_update,
    flatten_updates,
)
from repro.core import election as election_mod
from repro.core.attacks import ATTACKS
from repro.core.blockchain import UPDATE
from repro.core.consensus import CommitteeConsensus
from repro.core.incentive import distribute_rewards
from repro.data import make_femnist_like
from repro.fl import (
    BFLCConfig,
    BFLCRuntime,
    FLConfig,
    FLTrainer,
    femnist_adapter,
)
from repro.fl.client import sample_client_batches
from repro.fl.pipeline import _stack, _unstack
from repro.fl.runtime import RoundLog

# full legacy-vs-pipeline round replays: one of the long parity suites
# (deselect with -m "not slow"; CI's fast lane does)
pytestmark = pytest.mark.slow


class LegacyBFLCRuntime(BFLCRuntime):
    """The pre-refactor ~180-line monolithic round, verbatim."""

    def run_round(self, eval_test: bool = False) -> RoundLog:
        cfg, rng = self.cfg, self.rng
        t, params = self.chain.latest_model()

        committee = [i for i in self.committee if i in self.manager.nodes]

        vpairs = [
            sample_client_batches(
                rng, self.data.client_images[j], self.data.client_labels[j],
                1, cfg.val_batch,
            )
            for j in committee
        ]
        vx = np.stack([p[0][0] for p in vpairs])
        vy = np.stack([p[1][0] for p in vpairs])

        consensus = CommitteeConsensus(
            committee,
            score_fn=None,  # bound per cohort below
            accept_threshold=cfg.accept_threshold,
        )

        all_updates = {}
        trainers_total = []
        attack = ATTACKS[cfg.attack]
        for cohort in range(3):
            active = self.manager.sample_active(rng, cfg.active_proportion)
            trainers = [
                i for i in active
                if i not in committee and i not in all_updates
            ][: self.p_trainers]
            if len(trainers) < self.p_trainers:
                extra = [
                    i for i in self.manager.active_ids()
                    if i not in committee and i not in all_updates
                    and i not in trainers
                ]
                need = min(self.p_trainers - len(trainers), len(extra))
                if need > 0:
                    trainers += rng.choice(
                        extra, size=need, replace=False
                    ).tolist()
            if not trainers:
                break

            pairs = [
                sample_client_batches(
                    rng, self.data.client_images[i],
                    self.data.client_labels[i],
                    cfg.local_steps, cfg.local_batch,
                )
                for i in trainers
            ]
            xs = np.stack([p[0] for p in pairs])
            ys = np.stack([p[1] for p in pairs])
            updates_stacked = self._local_train(params, xs, ys)
            updates = _unstack(updates_stacked, len(trainers))
            for idx, node_id in enumerate(trainers):
                if self.manager.nodes[node_id].is_malicious:
                    updates[idx] = attack(
                        rng, updates[idx], cfg.attack_sigma, ref=params
                    ) if cfg.attack == "gaussian" else attack(rng, updates[idx])

            honest_scores = np.asarray(
                self._score_matrix(params, _stack(updates), vx, vy)
            )
            score_table = {}
            for i, uploader in enumerate(trainers):
                row = {}
                for j, member in enumerate(committee):
                    s = float(honest_scores[i, j])
                    if cfg.collusion:
                        s = self._collusion.score(
                            rng,
                            self.manager.nodes[member].is_malicious,
                            self.manager.nodes[uploader].is_malicious,
                            s,
                        )
                    row[member] = s
                score_table[uploader] = row
            consensus.score_fn = lambda m, payload: score_table[payload][m]
            for idx, uploader in enumerate(trainers):
                consensus.validate(uploader, uploader)
                all_updates[uploader] = updates[idx]
            trainers_total += trainers
            if len(consensus.accepted_records()) >= cfg.k_updates:
                break

        records = sorted(
            consensus.accepted_records(), key=lambda r: -r.median_score
        )[: cfg.k_updates]
        if not records:
            records = sorted(
                consensus.records, key=lambda r: -r.median_score
            )[:1]
        while len(records) < cfg.k_updates:
            records.append(records[0])
        packed_ids = [r.uploader for r in records]
        packed_scores = [r.median_score for r in records]
        packed_updates = [all_updates[u] for u in packed_ids]
        trainers = trainers_total
        weights = packed_scores if cfg.weight_by_score else None

        if cfg.quantize_chain:
            import jax.numpy as jnp
            from repro.kernels.ops import aggregate_quantized, quantize_stack

            stack, unravel = flatten_updates(packed_updates)
            q, s, d = quantize_stack(stack)
            for i, (u, sc) in enumerate(zip(packed_ids, packed_scores)):
                self.chain.append_update(
                    {"q": q[i], "scales": s[i], "d": d}, u, sc, encoded=True
                )
                self.manager.nodes[u].score_history.append(sc)
            agg = unravel(aggregate_quantized(
                q, s, d, method=cfg.aggregation,
                weights=None if weights is None else jnp.asarray(weights),
                trim=cfg.trim,
            ))
        else:
            for i, (u, sc) in enumerate(zip(packed_ids, packed_scores)):
                self.chain.append_update(packed_updates[i], u, sc)
                self.manager.nodes[u].score_history.append(sc)

            agg = aggregate_pytrees(
                packed_updates, method=cfg.aggregation, weights=weights,
                trim=cfg.trim, use_kernels=cfg.use_kernels,
            )
        new_params = apply_update(params, agg)
        self.chain.append_model(new_params, t + 1)

        cand = dict(zip(packed_ids, packed_scores))
        self.committee = election_mod.elect(
            cfg.election_method, rng, cand, self.q_committee
        ) or committee
        self._fill_committee()
        distribute_rewards(self.manager, cand, cfg.reward_pool)
        if cfg.kick_below >= 0:
            for r in consensus.records:
                if r.median_score < cfg.kick_below:
                    self.manager.kick(r.uploader)
        if cfg.prune_keep_rounds > 0:
            self.chain.prune(cfg.prune_keep_rounds)

        mal_nodes = {i for i, nd in self.manager.nodes.items() if nd.is_malicious}
        log = RoundLog(
            round=t,
            trainers=len(trainers),
            committee=len(committee),
            accepted_malicious=sum(
                1 for r in consensus.accepted_records() if r.uploader in mal_nodes
            ),
            packed_malicious=sum(1 for u in packed_ids if u in mal_nodes),
            mean_packed_score=float(np.mean(packed_scores)) if packed_scores else 0.0,
            consensus_validations=consensus.stats.validations,
            test_accuracy=self.evaluate() if eval_test else None,
        )
        self.logs.append(log)
        return log


class LegacyFLTrainer(FLTrainer):
    """The pre-refactor baseline round, verbatim."""

    def run_round(self):
        cfg, rng = self.cfg, self.rng
        n = self.data.num_clients
        m = max(2, int(round(n * cfg.active_proportion)))
        active = rng.choice(n, m, replace=False)

        pairs = [
            sample_client_batches(rng, self.data.client_images[i],
                                  self.data.client_labels[i],
                                  cfg.local_steps, cfg.local_batch)
            for i in active
        ]
        xs = np.stack([p[0] for p in pairs])
        ys = np.stack([p[1] for p in pairs])
        stacked = self._local_train(self.params, xs, ys)
        updates = [jax.tree.map(lambda x: x[i], stacked) for i in range(m)]
        attack = ATTACKS[cfg.attack]
        for idx, node in enumerate(active):
            if int(node) in self.malicious:
                updates[idx] = attack(
                    rng, updates[idx], cfg.attack_sigma, ref=self.params
                ) if cfg.attack == "gaussian" else attack(rng, updates[idx])

        weights = None
        if cfg.size_weighted and cfg.aggregation == "fedavg":
            weights = [len(self.data.client_labels[i]) for i in active]
        agg = aggregate_pytrees(updates, method=cfg.aggregation, weights=weights)
        self.params = apply_update(self.params, agg)


@pytest.fixture(scope="module")
def small_ds():
    return make_femnist_like(
        num_clients=24, mean_samples=40, test_size=200, seed=3
    )


@pytest.fixture(scope="module")
def adapter():
    return femnist_adapter(width=8)


def _chain_fingerprint(chain):
    return (
        chain.height,
        [b.hash for b in chain.blocks],
        [b.uploader for b in chain.blocks if b.kind == UPDATE],
        [b.score for b in chain.blocks if b.kind == UPDATE],
    )


def _run_both(small_ds, adapter, cfg, rounds=2):
    new = BFLCRuntime(adapter, small_ds, cfg)
    legacy = LegacyBFLCRuntime(adapter, small_ds, cfg)
    new_logs = new.run(rounds, eval_every=rounds)
    legacy_logs = legacy.run(rounds, eval_every=rounds)
    return new, legacy, new_logs, legacy_logs


CFG_KW = dict(active_proportion=0.5, committee_fraction=0.3,
              k_updates=4, local_steps=3, local_batch=8,
              malicious_fraction=0.25, attack_sigma=1.5, seed=0)


def test_pipeline_parity_f32(small_ds, adapter):
    cfg = BFLCConfig(**CFG_KW)
    new, legacy, new_logs, legacy_logs = _run_both(small_ds, adapter, cfg)
    assert _chain_fingerprint(new.chain) == _chain_fingerprint(legacy.chain)
    assert new_logs == legacy_logs
    assert new.committee == legacy.committee
    assert new.chain.verify() and legacy.chain.verify()


def test_pipeline_parity_quantized(small_ds, adapter):
    cfg = BFLCConfig(quantize_chain=True, use_kernels=True, **CFG_KW)
    new, legacy, new_logs, legacy_logs = _run_both(small_ds, adapter, cfg)
    assert _chain_fingerprint(new.chain) == _chain_fingerprint(legacy.chain)
    assert new_logs == legacy_logs
    # int8 blobs on chain in both
    assert new.chain.blocks[1].encoded and legacy.chain.blocks[1].encoded


def test_pipeline_parity_rewards_and_membership(small_ds, adapter):
    cfg = BFLCConfig(kick_below=0.05, **CFG_KW)
    new, legacy, _, _ = _run_both(small_ds, adapter, cfg)
    assert sorted(new.manager.nodes) == sorted(legacy.manager.nodes)
    assert new.manager.blacklist == legacy.manager.blacklist
    assert {i: n.tokens for i, n in new.manager.nodes.items()} == \
           {i: n.tokens for i, n in legacy.manager.nodes.items()}


def test_baseline_parity(small_ds, adapter):
    for method in ("fedavg", "cwmed"):
        kw = dict(active_proportion=0.4, local_steps=3, local_batch=8,
                  aggregation=method, malicious_fraction=0.25, seed=0)
        new = FLTrainer(adapter, small_ds, FLConfig(**kw))
        legacy = LegacyFLTrainer(adapter, small_ds, FLConfig(**kw))
        new.run(2, eval_every=2)
        legacy.run(2, eval_every=2)
        assert new.accuracies == legacy.accuracies
        for a, b in zip(jax.tree.leaves(new.params),
                        jax.tree.leaves(legacy.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
