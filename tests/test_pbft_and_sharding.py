"""PBFT accounting + sharding-policy unit/property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.core.pbft import (
    pbft_fault_tolerance,
    pbft_instance_messages,
    round_messages,
)
from repro.configs import registry
from repro.launch.shardings import ShardingPolicy, batch_pspecs, param_pspecs
from repro.models import init_model


# ---------------------------------------------------------------------------
# PBFT accounting
# ---------------------------------------------------------------------------


def test_pbft_message_formula():
    assert pbft_instance_messages(1) == 0
    assert pbft_instance_messages(4) == 3 + 2 * 4 * 3


def test_pbft_fault_tolerance():
    assert pbft_fault_tolerance(4) == 1
    assert pbft_fault_tolerance(7) == 2
    assert pbft_fault_tolerance(1) == 0


@given(P_=st.integers(2, 200), Q=st.integers(2, 60), k=st.integers(1, 20))
@settings(max_examples=40, deadline=None)
def test_property_ccm_beats_network_pbft(P_, Q, k):
    m = round_messages(P_, Q, k)
    # committee consensus + validation always cheaper than network-wide PBFT
    assert m.total_ccm < m.network_pbft + m.validation
    if P_ >= Q:  # the paper's regime (committee is a minority)
        assert m.committee_pbft < m.network_pbft


# ---------------------------------------------------------------------------
# sharding policy
# ---------------------------------------------------------------------------

POL = ShardingPolicy(dp_axes=("data",), dp_sizes=(16,), model_axis_size=16)


@pytest.mark.parametrize("arch", ["olmo-1b", "mixtral-8x7b", "rwkv6-7b",
                                  "jamba-1.5-large-398b", "hubert-xlarge"])
def test_param_pspecs_match_tree_and_ranks(arch):
    cfg = registry.smoke_config(arch)
    params = jax.eval_shape(
        lambda: init_model(jax.random.PRNGKey(0), cfg)
    )
    specs = param_pspecs(cfg, params, POL)
    # same tree structure
    assert jax.tree.structure(
        jax.tree.map(lambda x: 0, params)
    ) == jax.tree.structure(
        jax.tree.map(lambda s: 0, specs, is_leaf=lambda x: isinstance(x, P))
    )
    # every spec rank <= leaf rank
    leaves = jax.tree.leaves(params)
    spec_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for leaf, spec in zip(leaves, spec_leaves):
        assert len(spec) <= leaf.ndim, (spec, leaf.shape)


def test_divisibility_guard_hubert_head():
    """The 504-class head must stay replicated on a 16-way model axis."""
    cfg = registry.get_config("hubert-xlarge")
    params = jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))
    specs = param_pspecs(cfg, params, POL)
    lm = specs["lm_head"]
    # dim 1 (504) must not be sharded 16-way
    assert len(lm) < 2 or lm[1] is None


def _norm(spec):
    """PartitionSpec entries version-agnostic: newer jax flattens singleton
    axis tuples (('data',) -> 'data'), 0.4.x keeps them — compare both."""
    out = []
    for e in spec:
        if isinstance(e, (tuple, list)) and len(e) == 1:
            e = e[0]
        out.append(e)
    return tuple(out)


def test_full_config_param_specs_shard_big_matrices():
    cfg = registry.get_config("olmo-1b")
    params = jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))
    specs = param_pspecs(cfg, params, POL)
    wq = specs["units"][0]["mixer"]["wq"]
    assert _norm(wq) == (None, "data", "model")


def test_batch_pspecs_mrope():
    cfg = registry.get_config("qwen2-vl-7b")
    b = batch_pspecs(cfg, POL, batch_sharded=True)
    assert _norm(b.positions) == (None, "data", None)
    assert _norm(b.tokens) == ("data", None)


def test_axis_size_resolution():
    assert POL.axis_size(None) == 1
    assert POL.axis_size("model") == 16
    assert POL.axis_size(("data", "model")) == 256


# ---------------------------------------------------------------------------
# MoE dispatch positions (sort-based ranking)
# ---------------------------------------------------------------------------


@given(
    a=st.integers(4, 200), e=st.integers(2, 16), c=st.integers(1, 16),
)
@settings(max_examples=30, deadline=None)
def test_property_dispatch_positions(a, e, c):
    from repro.models.moe import _dispatch_positions

    rng = np.random.default_rng(a * 7 + e)
    ids = jnp.asarray(rng.integers(0, e, a), jnp.int32)
    pos, keep = _dispatch_positions(ids, e, c)
    pos, keep, idsn = np.asarray(pos), np.asarray(keep), np.asarray(ids)
    # within each expert, kept slots are unique and < capacity
    for ex in range(e):
        slots = pos[(idsn == ex) & keep]
        assert len(set(slots.tolist())) == len(slots)
        assert (slots < c).all()
        # number kept = min(count, capacity)
        assert len(slots) == min((idsn == ex).sum(), c)
