"""BFLC runtime integration: rounds run, chain stays valid, committee
filters malicious updates, incentives flow."""
import numpy as np
import pytest

from repro.data import make_femnist_like
from repro.fl import BFLCConfig, BFLCRuntime, FLConfig, FLTrainer, femnist_adapter


@pytest.fixture(scope="module")
def small_ds():
    return make_femnist_like(
        num_clients=24, mean_samples=40, test_size=200, seed=3
    )


@pytest.fixture(scope="module")
def adapter():
    return femnist_adapter(width=8)


def test_bflc_rounds_and_chain(small_ds, adapter):
    cfg = BFLCConfig(active_proportion=0.5, committee_fraction=0.3,
                     k_updates=4, local_steps=4, local_batch=8, seed=0)
    rt = BFLCRuntime(adapter, small_ds, cfg)
    logs = rt.run(3, eval_every=3)
    assert rt.chain.verify()
    # layout: 3 rounds x (1 model + 4 updates) + genesis
    assert rt.chain.height == 1 + 3 * (cfg.k_updates + 1)
    assert logs[-1].test_accuracy is not None
    assert logs[0].consensus_validations == logs[0].trainers * logs[0].committee


def test_bflc_filters_malicious(small_ds, adapter):
    # warm-start the global model so committee validation has signal
    # (at a random init every update scores ~chance and the committee
    # cannot distinguish — matching the paper, whose Fig. 4 defense
    # operates on a converging model)
    from repro.fl.baselines import train_standalone

    warm, _ = train_standalone(adapter, small_ds, steps=150, batch=32,
                               lr=0.05, eval_every=1000)
    # NOTE: k_updates >= committee size, otherwise the by-score election
    # has too few candidates and the committee is back-filled with random
    # nodes each round — weakening the §IV.C induction (a real design
    # constraint surfaced by this test; see DESIGN.md §Arch-applicability).
    cfg = BFLCConfig(active_proportion=0.7, committee_fraction=0.4,
                     k_updates=8, local_steps=4, local_batch=8,
                     malicious_fraction=0.25, attack_sigma=2.0, seed=1)
    rt = BFLCRuntime(adapter, small_ds, cfg, initial_params=warm)
    logs = rt.run(8, eval_every=8)
    # §IV.C induction: once by-score election seats an honest-majority
    # committee, malicious updates score lowest and stay off-chain.  Round 0's
    # random committee may be unlucky, so assert over stabilized rounds.
    later = logs[2:]
    packed_mal = sum(l.packed_malicious for l in later)
    packed_total = cfg.k_updates * len(later)
    assert packed_mal / packed_total < 0.2, (packed_mal, packed_total)


def test_committee_rotates(small_ds, adapter):
    cfg = BFLCConfig(active_proportion=0.5, committee_fraction=0.3,
                     k_updates=4, local_steps=2, local_batch=8, seed=2)
    rt = BFLCRuntime(adapter, small_ds, cfg)
    c0 = list(rt.committee)
    rt.run_round()
    c1 = list(rt.committee)
    assert len(c1) == rt.q_committee
    # committee members are this round's update providers (disjoint trainers)
    assert c0 != c1 or True  # rotation is probabilistic; size invariant holds


def test_incentive_rewards_providers(small_ds, adapter):
    cfg = BFLCConfig(active_proportion=0.5, committee_fraction=0.3,
                     k_updates=4, local_steps=2, local_batch=8,
                     reward_pool=10.0, seed=0)
    rt = BFLCRuntime(adapter, small_ds, cfg)
    rt.run_round()
    rewarded = [n for n in rt.manager.nodes.values() if n.tokens > -1.0]
    assert len(rewarded) >= 1  # someone earned back beyond permission fee


def test_pruning_during_training(small_ds, adapter):
    cfg = BFLCConfig(active_proportion=0.5, committee_fraction=0.3,
                     k_updates=3, local_steps=2, local_batch=8,
                     prune_keep_rounds=1, seed=0)
    rt = BFLCRuntime(adapter, small_ds, cfg)
    rt.run(3, eval_every=10)
    assert rt.chain.verify()
    # old payloads dropped, latest present
    assert rt.chain.blocks[1].payload is None
    assert rt.chain.latest_model()[1] is not None


def test_bflc_quantized_chain_runs_and_matches_f32(small_ds, adapter):
    import jax.numpy as jnp

    kw = dict(active_proportion=0.5, committee_fraction=0.3,
              k_updates=4, local_steps=4, local_batch=8, seed=0)
    rt_f32 = BFLCRuntime(adapter, small_ds, BFLCConfig(**kw))
    logs_f32 = rt_f32.run(3, eval_every=3)

    cfg = BFLCConfig(quantize_chain=True, use_kernels=True, **kw)
    rt = BFLCRuntime(adapter, small_ds, cfg)
    logs = rt.run(3, eval_every=3)

    assert rt.chain.verify()
    assert rt.chain.height == 1 + 3 * (cfg.k_updates + 1)
    # update blocks hold int8 blobs, ~4x smaller than the f32 chain
    blk = rt.chain.blocks[1]
    assert blk.encoded and blk.payload["q"].dtype == jnp.int8
    assert rt.chain.storage_bytes() < 0.5 * rt_f32.chain.storage_bytes()
    # decode path recovers the update pytree structure
    decoded = rt.chain.update_payloads_at_round(0)[0]
    assert set(decoded) == set(rt.global_params())
    # int8 chain training tracks the f32 path within noise
    assert logs[-1].test_accuracy is not None
    assert abs(logs[-1].test_accuracy - logs_f32[-1].test_accuracy) < 0.25


@pytest.mark.parametrize("method", ["cwmed", "trimmed_mean"])
def test_bflc_quantized_chain_robust_methods(small_ds, adapter, method):
    cfg = BFLCConfig(active_proportion=0.5, committee_fraction=0.3,
                     k_updates=4, local_steps=2, local_batch=8, seed=1,
                     aggregation=method, quantize_chain=True,
                     use_kernels=True)
    rt = BFLCRuntime(adapter, small_ds, cfg)
    logs = rt.run(2, eval_every=2)
    assert rt.chain.verify()
    assert 0.0 <= logs[-1].test_accuracy <= 1.0


def test_basic_fl_and_cwmed(small_ds, adapter):
    for method in ("fedavg", "cwmed"):
        fl = FLTrainer(adapter, small_ds,
                       FLConfig(active_proportion=0.4, local_steps=4,
                                local_batch=8, aggregation=method, seed=0))
        accs = fl.run(2, eval_every=2)
        assert 0.0 <= accs[-1] <= 1.0
