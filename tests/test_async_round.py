"""Parity + failure-edge suite for the async pipelined round engine.

The async engine (``repro.fl.async_engine``) replaces the round
*schedule*, not the stages: every test here runs the SAME community/seed
through ``schedule="sequential"`` and ``schedule="async"`` and demands
bit-identical products — chain fingerprints (block hashes, packed
uploader ids, scores), ``RoundLog``s, and aggregated params — across

* the flat f32 engine, malicious (rng-serialized regime) and clean
  (overlapped regime) — the rng-edge chaining must hold in both;
* the sharded fused-int8 engine on 1/2/8 forced CPU devices;
* the hierarchical two-tier engine (slice pipelining), int8+mesh and f32;
* the committee-free FLTrainer baselines.

Failure edges: a stage raising mid-ring must abort the round with the
chain untouched (no torn layout — all appends live in the tail), and
``max_cohorts`` exhaustion must drain the ring cleanly and still match
the sequential engine bit for bit.

The row_quant staleness regression (rows cached for an earlier cohort
leaking onto the chain as stale blobs when an uploader is re-drawn) is
pinned here too: it fails on the engine without the cohort-boundary
``ctx.row_quant.clear()``.
"""
import jax
import numpy as np
import pytest

from repro.api import build_runtime
from repro.core.blockchain import UPDATE
from repro.data import make_femnist_like
from repro.fl import femnist_adapter
from repro.fl.async_engine import AsyncRoundPipeline, SLOT_FIELDS
from repro.fl.pipeline import (
    STAGE_TIMING_KEYS,
    CommitteeValidator,
    RoundContext,
    _sync_tree,
    cache_row_quant,
    pack_top_k_int8,
    resolve,
)

DEVICE_COUNTS = (1, 2, 8)

CFG = dict(active_proportion=0.5, committee_fraction=0.3, k_updates=4,
           local_steps=3, local_batch=8, malicious_fraction=0.25,
           attack_sigma=1.5, seed=0)

# small/fast variant for the failure-edge tests
FAST = dict(CFG, local_steps=2)


@pytest.fixture(scope="module")
def ds():
    return make_femnist_like(num_clients=24, mean_samples=40,
                             test_size=200, seed=3)


@pytest.fixture(scope="module")
def adapter():
    return femnist_adapter(width=8)


def _chain_fingerprint(chain):
    return (
        chain.height,
        [b.hash for b in chain.blocks],
        [b.uploader for b in chain.blocks if b.kind == UPDATE],
        [b.score for b in chain.blocks if b.kind == UPDATE],
    )


def _leaves_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _run_pair(adapter, ds, cfg, rounds=2, **kw):
    """The same config through both schedules -> (sequential, async)."""
    rt_seq = build_runtime(adapter, ds, dict(cfg), **kw)
    rt_async = build_runtime(adapter, ds, dict(cfg), schedule="async", **kw)
    logs_seq = rt_seq.run(rounds, eval_every=rounds)
    logs_async = rt_async.run(rounds, eval_every=rounds)
    return rt_seq, rt_async, logs_seq, logs_async


def _assert_parity(rt_seq, rt_async, logs_seq, logs_async,
                   hashes_equal=True):
    if hashes_equal:
        assert _chain_fingerprint(rt_seq.chain) == \
            _chain_fingerprint(rt_async.chain)
    assert logs_seq == logs_async
    assert rt_seq.committee == rt_async.committee
    assert rt_seq.chain.verify() and rt_async.chain.verify()
    _leaves_equal(rt_seq.global_params(), rt_async.global_params())


# ----------------------------------------------------------------------
# wiring
# ----------------------------------------------------------------------
def test_schedule_arg_validation(ds, adapter):
    with pytest.raises(ValueError, match="schedule"):
        build_runtime(adapter, ds, dict(CFG), schedule="overlapped")
    with pytest.raises(ValueError, match="schedule"):
        build_runtime(adapter, ds, {"seed": 0}, baseline=True,
                      schedule="overlapped")


def test_async_wraps_same_stage_set(ds, adapter):
    rt_seq = build_runtime(adapter, ds, dict(CFG))
    rt_async = build_runtime(adapter, ds, dict(CFG), schedule="async")
    assert isinstance(rt_async.pipeline, AsyncRoundPipeline)
    assert rt_async.schedule == "async"
    # same registered stage objects, different runner
    for kind in ("sampler", "local_trainer", "validator", "packer",
                 "aggregator", "elector", "rewarder"):
        assert getattr(rt_async.pipeline, kind) is \
            getattr(rt_seq.pipeline, kind)
    assert rt_async.pipeline.max_cohorts == rt_seq.pipeline.max_cohorts


def test_sync_tree_covers_inflight_fields():
    """The sequential driver's blanket sync must see every ctx field a
    stage can leave as in-flight device work — in particular the split
    stages' ``train_inflight`` / ``cohort_stacked`` / ``cohort_scores``
    (whose device time used to bleed into the next stage's bucket)."""
    sentinels = {f: object() for f in
                 ("cohort_updates", "cohort_stacked", "train_inflight",
                  "cohort_scores", "packed_quantized", "aggregate",
                  "new_params")}
    ctx = RoundContext(cfg=None, rng=np.random.default_rng(0),
                       adapter=None, data=None, params=None, round=0)
    for f, v in sentinels.items():
        setattr(ctx, f, v)
    synced = _sync_tree(ctx)
    for f, v in sentinels.items():
        assert any(s is v for s in synced), f"_sync_tree misses ctx.{f}"

    class _H:
        sub_aggregates = object()

    ctx.hier = _H()
    assert any(s is _H.sub_aggregates for s in _sync_tree(ctx))


def test_async_timing_schema(ds, adapter):
    """Async rounds keep the BENCH_round timing schema: every stage
    bucket present, train/validate buckets actually accumulate."""
    rt = build_runtime(adapter, ds, dict(FAST), schedule="async")
    rt.run_round()
    timings = rt.stage_timings[0]
    assert set(timings) == set(STAGE_TIMING_KEYS)
    assert timings["train"] > 0 and timings["validate"] > 0


# ----------------------------------------------------------------------
# failure edges
# ----------------------------------------------------------------------
class _Boom(Exception):
    pass


class _RaisingValidator:
    """Delegates to the committee validator; forces a second cohort and
    raises mid-ring (cohort 1's validate, with cohort work in flight)."""

    def __init__(self):
        self.inner = resolve("validator", "committee")
        self.cohorts_seen = []

    def prepare(self, ctx):
        self.inner.prepare(ctx)

    def __call__(self, ctx):
        self.cohorts_seen.append(ctx.cohort)
        if ctx.cohort >= 1:
            raise _Boom("mid-ring failure")
        self.inner(ctx)
        ctx.collected = False      # force the ring past cohort 0


@pytest.mark.parametrize("schedule", ("sequential", "async"))
def test_midring_failure_leaves_chain_untouched(ds, adapter, schedule):
    """A stage raising with a later cohort already in flight must not
    commit anything: every chain append lives in the tail, so the round
    aborts with the chain exactly as it started (no torn layout)."""
    val = _RaisingValidator()
    rt = build_runtime(adapter, ds, dict(FAST), stages={"validator": val},
                       schedule=schedule)
    h0 = rt.chain.height
    blocks0 = [b.hash for b in rt.chain.blocks]
    with pytest.raises(_Boom):
        rt.run_round()
    assert val.cohorts_seen == [0, 1]  # the failure really was mid-ring
    assert rt.chain.height == h0
    assert [b.hash for b in rt.chain.blocks] == blocks0
    assert rt.chain.verify()
    assert rt.logs == []               # no partial round log either


class _NeverCollect:
    """Committee validator that never fires the trigger: the ring runs
    to max_cohorts exhaustion and must drain cleanly."""

    def __init__(self):
        self.inner = resolve("validator", "committee")

    def prepare(self, ctx):
        self.inner.prepare(ctx)

    def __call__(self, ctx):
        self.inner(ctx)
        ctx.collected = False


def test_max_cohorts_exhaustion_drains_ring(ds, adapter):
    """collected never fires -> the engine runs all max_cohorts cohorts,
    drains the ring, runs the tail exactly once, and stays bit-identical
    to the sequential engine."""
    rt_seq = build_runtime(adapter, ds, dict(FAST),
                           stages={"validator": _NeverCollect()})
    rt_async = build_runtime(adapter, ds, dict(FAST),
                             stages={"validator": _NeverCollect()},
                             schedule="async")
    log_seq = rt_seq.run_round()
    log_async = rt_async.run_round()
    assert log_seq == log_async
    # all three cohorts ran: trainers accumulated past one cohort's worth
    assert log_async.trainers > rt_async.p_trainers
    assert _chain_fingerprint(rt_seq.chain) == \
        _chain_fingerprint(rt_async.chain)
    assert rt_async.chain.verify()
    # exactly one tail: k update blocks + one model block on top of genesis
    assert rt_async.chain.height == 1 + FAST["k_updates"] + 1


# ----------------------------------------------------------------------
# row_quant staleness regression (bugfix pin)
# ----------------------------------------------------------------------
class _StaleCacheValidator(CommitteeValidator):
    """Cohort 0: int8-scores the cohort (caching its per-row blobs) but
    admits nothing — forcing a second cohort that re-draws the same
    uploaders with NEW updates.  Without the engine's cohort-boundary
    ``ctx.row_quant.clear()`` the packer then reuses cohort 0's cached
    rows for cohort 1's packed updates: a stale blob on the chain."""

    def __call__(self, ctx):
        if ctx.cohort == 0:
            from repro.core.aggregation import flatten_updates

            stack, _ = flatten_updates(ctx.cohort_updates)
            _, q, s = ctx.int8_score_fn(
                ctx.params, stack, ctx.val_x, ctx.val_y
            )
            cache_row_quant(ctx, q, s, int(stack.shape[1]))
            ctx.trainers_total += list(ctx.trainers)
            return
        super().__call__(ctx)


def test_row_quant_cleared_between_cohorts(ds, adapter):
    """Regression: the packed chain blobs must quantize the updates that
    were actually packed — never rows cached for an earlier cohort's
    updates.  Fails on the engine without the cohort-boundary clear."""
    from repro.core.aggregation import flatten_updates
    from repro.kernels.ops import quantize_stack

    captured = {}

    def spy_packer(ctx):
        pack_top_k_int8(ctx)
        captured["q"] = np.asarray(ctx.packed_quantized[0])
        captured["s"] = np.asarray(ctx.packed_quantized[1])
        captured["updates"] = [jax.tree.map(np.asarray, u)
                               for u in ctx.packed_updates]

    cfg = dict(active_proportion=1.0, committee_fraction=0.3, k_updates=4,
               local_steps=2, local_batch=8, quantize_chain=True,
               use_kernels=True, seed=0)
    rt = build_runtime(adapter, ds, cfg,
                       stages={"validator": _StaleCacheValidator(),
                               "packer": spy_packer})
    rt.run_round()

    stack, _ = flatten_updates(captured["updates"])
    q_fresh, s_fresh, _ = quantize_stack(stack)
    np.testing.assert_array_equal(captured["q"], np.asarray(q_fresh))
    np.testing.assert_array_equal(captured["s"], np.asarray(s_fresh))


# ----------------------------------------------------------------------
# full parity: sequential vs async, bit for bit
# ----------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("malicious", (True, False),
                         ids=("malicious", "clean"))
def test_async_flat_f32_parity(ds, adapter, malicious):
    """Flat f32 rounds: with malicious trainers the rng edges serialize
    the graph (the regime where a reordered draw would flip the chain);
    clean rounds overlap train/validate — both must be bit-identical."""
    cfg = dict(CFG) if malicious else dict(CFG, malicious_fraction=0.0)
    _assert_parity(*_run_pair(adapter, ds, cfg))


@pytest.mark.slow
@pytest.mark.parametrize("ndev", DEVICE_COUNTS)
def test_async_sharded_int8_parity(round_mesh, ds, adapter, ndev):
    """Sharded fused-int8 rounds on 1/2/8 devices: the async schedule
    overlaps cohort t+1's shard_mapped training with cohort t's
    committee work and must reproduce every chain bit."""
    mesh = round_mesh(ndev)
    cfg = dict(CFG, quantize_chain=True, use_kernels=True)
    _assert_parity(*_run_pair(adapter, ds, cfg, mesh=mesh))


@pytest.mark.slow
@pytest.mark.parametrize("quantized", (True, False), ids=("int8", "f32"))
def test_async_tiered_parity(round_mesh, ds, adapter, quantized):
    """Hierarchical two-tier rounds: the prefetch_safe tiered sampler
    lets slice s+1 train while slice s sub-aggregates — the headline
    overlap — and the chain must still match the sequential engine."""
    if quantized:
        cfg = dict(CFG, active_proportion=1.0, quantize_chain=True,
                   use_kernels=True, tiers=2)
        kw = {"mesh": round_mesh(2)}
    else:
        cfg = dict(CFG, active_proportion=1.0, malicious_fraction=0.0,
                   tiers=2)
        kw = {}
    rt_seq, rt_async, logs_seq, logs_async = _run_pair(
        adapter, ds, cfg, **kw
    )
    _assert_parity(rt_seq, rt_async, logs_seq, logs_async)
    assert rt_seq.hier_logs == rt_async.hier_logs


@pytest.mark.slow
def test_async_baseline_parity(ds, adapter):
    """FLTrainer (committee-free) under the async schedule: same params,
    same accuracies."""
    cfg = dict(active_proportion=0.5, local_steps=2, local_batch=8,
               malicious_fraction=0.25, seed=0)
    bl_seq = build_runtime(adapter, ds, dict(cfg), baseline=True)
    bl_async = build_runtime(adapter, ds, dict(cfg), baseline=True,
                             schedule="async")
    bl_seq.run(2)
    bl_async.run(2)
    assert bl_seq.accuracies == bl_async.accuracies
    _leaves_equal(bl_seq.params, bl_async.params)


def test_slot_fields_match_context():
    """Every ring-slot field must exist on RoundContext (the executor
    stages them attribute-by-attribute)."""
    ctx = RoundContext(cfg=None, rng=np.random.default_rng(0),
                       adapter=None, data=None, params=None, round=0)
    for f in SLOT_FIELDS:
        assert hasattr(ctx, f)
