"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, shape + finiteness assertions; decode step for
decoder archs (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.launch.mesh import make_host_mesh
from repro.launch.shardings import ShardingPolicy
from repro.launch.steps import TrainState, make_train_step
from repro.models import decode_step, forward, init_model, prefill
from repro.models.frontends import hubert_batch, lm_batch, vlm_batch
from repro.optim import adamw

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def make_batch(cfg):
    if cfg.frontend == "audio":
        return hubert_batch(KEY, cfg, B, S)
    if cfg.frontend == "vision":
        return vlm_batch(KEY, cfg, B, S, image_patches=6, grid=(2, 3))
    return lm_batch(KEY, cfg, B, S)


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_smoke_forward(arch):
    cfg = registry.smoke_config(arch)
    assert cfg.d_model <= 512 and cfg.num_experts <= 4
    params = init_model(KEY, cfg)
    batch = make_batch(cfg)
    logits, aux = forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any()


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = registry.smoke_config(arch)
    mesh = make_host_mesh(1, 1)
    pol = ShardingPolicy(dp_axes=("data",), dp_sizes=(1,), model_axis_size=1, fsdp=False)
    opt = adamw(1e-3)
    step = make_train_step(cfg, opt, mesh, pol, mode="standard")
    params = init_model(KEY, cfg)
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    batch = make_batch(cfg)
    new_state, metrics = jax.jit(step)(state, batch, None)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        new_state.params, params,
    )
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize(
    "arch",
    [a for a in registry.ARCH_IDS
     if registry.smoke_config(a).is_decoder()],
)
def test_smoke_decode_step(arch):
    cfg = registry.smoke_config(arch)
    params = init_model(KEY, cfg)
    batch = make_batch(cfg)
    _, cache = prefill(params, cfg, batch, max_len=S + 4)
    tok = jnp.full((B, 1), 1, jnp.int32)
    pos = jnp.full((B,), S, jnp.int32)
    mrope = (jnp.broadcast_to(pos[None, :, None], (3, B, 1))
             if cfg.rope == "mrope" else None)
    logits, new_cache = decode_step(
        params, cfg, tok, pos, cache, mrope_position=mrope
    )
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any()


def test_encoder_has_no_decode():
    cfg = registry.smoke_config("hubert-xlarge")
    with pytest.raises(ValueError):
        decode_step(init_model(KEY, cfg), cfg, jnp.zeros((1, 1), jnp.int32),
                    jnp.zeros((1,), jnp.int32), {})


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_full_config_matches_spec(arch):
    """The FULL configs carry the exact assigned hyper-parameters."""
    cfg = registry.get_config(arch)
    spec = {
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "rwkv6-7b": (32, 4096, 0, 0, 14336, 65536),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
    }[arch]
    L, D, H, KV, FF, V = spec
    assert cfg.num_layers == L and cfg.d_model == D
    assert cfg.num_heads == H and cfg.num_kv_heads == KV
    assert cfg.d_ff == FF and cfg.vocab_size == V
    moe = {
        "mixtral-8x7b": (8, 2),
        "qwen3-moe-30b-a3b": (128, 8),
        "jamba-1.5-large-398b": (16, 2),
    }
    if arch in moe:
        assert (cfg.num_experts, cfg.num_experts_per_tok) == moe[arch]
    else:
        assert cfg.num_experts == 0
