"""Differential test harness for the sharded round engine.

Every test runs the SAME community/seed through the single-device stages
and the sharded stages (1, 2 and 8 forced CPU devices — conftest forces
``--xla_force_host_platform_device_count=8``) and compares:

* f32 path (``local_sgd_sharded`` + ``committee_sharded`` + dense
  aggregation): update pytrees allclose AND chain fingerprints (block
  hashes, packed uploader ids) and ``RoundLog``s **identical** — per-client
  local SGD and per-candidate committee scoring are the same XLA programs
  on every device, so sharding may not change a single bit (the full-round
  parity tests below exercise the sharded P x Q validator implicitly: it
  is the default whenever a mesh is passed, and score medians land on the
  chain as block scores);
* int8 path (``top_k_int8_sharded`` + ``fused_int8_sharded``): the sharded
  codec pads D to the shard boundary, so chain blobs differ in length and
  hashes legitimately diverge — the aggregated model params must stay
  within tolerance (they are tile-aligned, so in practice bitwise equal)
  and the ``RoundLog``s identical;
* the padding path: P (trainers per cohort) NOT divisible by the device
  count.

This is the harness the attack-scenario and kernel tests ride on: a
regression anywhere in the sharded engine shows up as a hash or log
mismatch against the single-device oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import build_runtime
from repro.core.blockchain import UPDATE
from repro.data import make_femnist_like
from repro.fl import femnist_adapter
from repro.fl.client import (
    make_local_train_fn,
    make_score_from_int8_fn,
    make_score_matrix_fn,
    make_sharded_local_train_fn,
    make_sharded_score_from_int8_fn,
    make_sharded_score_matrix_fn,
)
from repro.launch.shardings import round_engine_pspecs, score_matrix_pspecs

DEVICE_COUNTS = (1, 2, 8)

CFG = dict(active_proportion=0.5, committee_fraction=0.3, k_updates=4,
           local_steps=3, local_batch=8, malicious_fraction=0.25,
           attack_sigma=1.5, seed=0)


@pytest.fixture(scope="module")
def ds():
    return make_femnist_like(num_clients=24, mean_samples=40,
                             test_size=200, seed=3)


@pytest.fixture(scope="module")
def adapter():
    return femnist_adapter(width=8)


def _chain_fingerprint(chain):
    return (
        chain.height,
        [b.hash for b in chain.blocks],
        [b.uploader for b in chain.blocks if b.kind == UPDATE],
        [b.score for b in chain.blocks if b.kind == UPDATE],
    )


def _leaves_allclose(a, b, atol=0.0):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=atol)


# ----------------------------------------------------------------------
# trainer-level differential: shard_map vs vmap, including padding
# ----------------------------------------------------------------------
@pytest.mark.parametrize("ndev", DEVICE_COUNTS)
@pytest.mark.parametrize("P", (8, 5))   # 5: P % ndev != 0 -> padding path
def test_sharded_trainer_matches_vmapped(round_mesh, adapter, ndev, P):
    mesh = round_mesh(ndev)
    params = adapter.init(jax.random.PRNGKey(0))
    single = make_local_train_fn(adapter, 0.05, 0.9)
    sharded = make_sharded_local_train_fn(adapter, 0.05, mesh, momentum=0.9)
    rng = np.random.default_rng(7)
    xs = rng.normal(size=(P, 3, 8, 28, 28, 1)).astype(np.float32)
    ys = rng.integers(0, 62, (P, 3, 8))
    pad = (-P) % ndev
    xs_p = np.concatenate([xs, np.repeat(xs[-1:], pad, axis=0)])
    ys_p = np.concatenate([ys, np.repeat(ys[-1:], pad, axis=0)])
    u_sh = jax.tree.map(lambda x: x[:P], sharded(params, xs_p, ys_p))
    u_1 = single(params, xs, ys)
    # same per-client XLA program -> bitwise equality, not just allclose
    _leaves_allclose(u_sh, u_1, atol=0.0)


# ----------------------------------------------------------------------
# validator-level differential: sharded P x Q score matrix vs the
# single-device oracle, including the P-padding path
# ----------------------------------------------------------------------
def _score_inputs(adapter, P, Q=3, vb=16, seed=11):
    params = adapter.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(seed)
    scale = 0.02
    updates = jax.tree.map(
        lambda p: jnp.asarray(
            rng.normal(0, scale * (np.abs(np.asarray(p)).mean() + 1e-3),
                       (P,) + p.shape), jnp.float32),
        params,
    )
    vx = np.asarray(rng.normal(size=(Q, vb, 28, 28, 1)), np.float32)
    vy = np.asarray(rng.integers(0, 62, (Q, vb)))
    return params, updates, vx, vy


def _pad_update_rows(updates, P, ndev):
    # the engine's own padding rule: the differential check below is the
    # bitwise comparison against the single-device oracle, so the test
    # must pad exactly as the sharded validator does
    from repro.fl.sharded import _pad_rows

    return _pad_rows(updates, P, ndev)


@pytest.mark.parametrize("ndev", DEVICE_COUNTS)
@pytest.mark.parametrize("P", (8, 5))   # 5: P % ndev != 0 -> padding path
def test_sharded_score_matrix_matches_oracle(round_mesh, adapter, ndev, P):
    """The f32 sharded validator program reproduces the single-device
    score matrix bit-for-bit — same per-candidate XLA program, sharded."""
    mesh = round_mesh(ndev)
    params, updates, vx, vy = _score_inputs(adapter, P)
    oracle = make_score_matrix_fn(adapter)
    sharded = make_sharded_score_matrix_fn(adapter, mesh)
    want = np.asarray(oracle(params, updates, vx, vy))
    got = np.asarray(
        sharded(params, _pad_update_rows(updates, P, ndev), vx, vy)
    )[:P]
    assert want.shape == got.shape == (P, vx.shape[0])
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("ndev", DEVICE_COUNTS)
def test_int8_score_matrix_parity(round_mesh, adapter, ndev):
    """The fused score-from-int8 path: bitwise identical across device
    counts (row-local tiles), bitwise identical to the staged
    dequantize-then-score oracle, and tolerance-bounded against the f32
    scores (int8 quantization noise only).  The scorers also return the
    per-row (q, scales) — the chain blobs the packers reuse — and the
    sharded variant consumes the stacked update pytree in-program
    (``flatten_stacked_updates``), so both paths must agree bitwise on
    rows too."""
    from jax.flatten_util import ravel_pytree

    from repro.kernels import ops

    mesh = round_mesh(ndev)
    P = 8
    params, updates, vx, vy = _score_inputs(adapter, P)
    flat_params, unravel = ravel_pytree(params)
    stack = jnp.stack(
        [ravel_pytree(jax.tree.map(lambda x: x[i], updates))[0]
         for i in range(P)]
    )

    single = make_score_from_int8_fn(adapter, unravel)
    sharded = make_sharded_score_from_int8_fn(adapter, mesh, unravel)
    want, q1, s1 = single(params, stack, vx, vy)
    want = np.asarray(want)
    # sharded scorer takes the trainer's stacked pytree, not a flat stack
    got, qn, sn = sharded(params, updates, vx, vy)
    np.testing.assert_array_equal(np.asarray(got), want)
    # per-row quantization is row-local: identical blobs on every ndev
    np.testing.assert_array_equal(np.asarray(qn), np.asarray(q1))
    np.testing.assert_array_equal(np.asarray(sn), np.asarray(s1))

    # staged oracle: quantize rows, dequantize to f32, score with the f32
    # program — the fused kernel performs the same ops in one pass (an fma
    # contraction of base + q*scale may flip an exactly-borderline argmax,
    # so allow at most one flipped sample per (i, j) cell)
    vb = vy.shape[1]
    q, s, d = ops.quantize_stack(stack)
    deq = jnp.stack([ops.dequantize(q[i], s[i], d) for i in range(P)])
    staged_updates = jax.vmap(unravel)(deq)
    oracle = make_score_matrix_fn(adapter)
    staged = np.asarray(oracle(params, staged_updates, vx, vy))
    assert np.abs(want - staged).max() <= 1.0 / vb + 1e-6

    # quantization noise moves accuracies, but only within int8 tolerance
    f32 = np.asarray(oracle(params, updates, vx, vy))
    assert np.abs(want - f32).max() <= 0.25


# ----------------------------------------------------------------------
# full-round differential: f32 engine (hash-identical)
# ----------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("ndev", DEVICE_COUNTS)
def test_f32_round_parity(round_mesh, ds, adapter, ndev):
    mesh = round_mesh(ndev)
    rt1 = build_runtime(adapter, ds, dict(CFG))
    rtn = build_runtime(adapter, ds, dict(CFG), mesh=mesh)
    logs1 = rt1.run(2, eval_every=2)
    logsn = rtn.run(2, eval_every=2)
    assert _chain_fingerprint(rt1.chain) == _chain_fingerprint(rtn.chain)
    assert logs1 == logsn
    assert rt1.committee == rtn.committee
    assert rt1.chain.verify() and rtn.chain.verify()
    _leaves_allclose(rt1.global_params(), rtn.global_params())


# ----------------------------------------------------------------------
# full-round differential: fused-int8 engine (tolerance-bounded)
# ----------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("ndev", DEVICE_COUNTS)
def test_int8_round_parity(round_mesh, ds, adapter, ndev):
    mesh = round_mesh(ndev)
    q_cfg = dict(CFG, quantize_chain=True, use_kernels=True)
    rt1 = build_runtime(adapter, ds, dict(q_cfg))
    rtn = build_runtime(adapter, ds, dict(q_cfg), mesh=mesh)
    logs1 = rt1.run(2, eval_every=2)
    logsn = rtn.run(2, eval_every=2)
    # blobs carry shard padding -> hashes may differ; behaviour may not
    assert logs1 == logsn
    assert rt1.committee == rtn.committee
    assert rt1.chain.verify() and rtn.chain.verify()
    # D-shards are tile-aligned: per-tile scales coincide with the
    # single-device codec, so the aggregate is equal to f32 rounding
    _leaves_allclose(rt1.global_params(), rtn.global_params(), atol=1e-6)
    # both chains store decodable int8 blobs with identical real content
    b1 = rt1.chain.update_payloads_at_round(0)
    bn = rtn.chain.update_payloads_at_round(0)
    for u1, un in zip(b1, bn):
        _leaves_allclose(u1, un, atol=1e-6)
    assert all(b.encoded for b in rtn.chain.updates_at_round(0))


# ----------------------------------------------------------------------
# row-quant cache: packers reuse the validator's per-row (q, scales)
# ----------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("sharded", (False, True))
def test_row_quant_cache_parity(round_mesh, ds, adapter, sharded):
    """With an int8-view validator the packer consumes the cached per-row
    (q, scales) instead of re-quantizing; dropping the cache (forcing the
    re-quantize path) must not change a single chain bit — the cached rows
    ARE the blobs the packer would have produced."""
    from repro.fl.pipeline import resolve

    q_cfg = dict(CFG, quantize_chain=True, use_kernels=True)
    mesh = round_mesh(2) if sharded else None
    validator = "committee_int8_sharded" if sharded else "committee_int8"
    packer_name = "top_k_int8_sharded" if sharded else "top_k_int8"
    packer = resolve("packer", packer_name)

    def no_cache_packer(ctx):
        ctx.row_quant.clear()
        packer(ctx)

    rt_cache = build_runtime(adapter, ds, dict(q_cfg), mesh=mesh,
                             stages={"validator": validator})
    rt_nocache = build_runtime(adapter, ds, dict(q_cfg), mesh=mesh,
                               stages={"validator": validator,
                                       "packer": no_cache_packer})
    logs_c = rt_cache.run(2, eval_every=2)
    logs_n = rt_nocache.run(2, eval_every=2)
    assert _chain_fingerprint(rt_cache.chain) == \
        _chain_fingerprint(rt_nocache.chain)
    assert logs_c == logs_n
    assert rt_cache.chain.verify()


@pytest.mark.parametrize("ndev", (2, 8))
def test_baseline_sharded_parity(round_mesh, ds, adapter, ndev):
    """FLTrainer (Basic FL / CwMed) with a mesh: the committee-free
    pipeline rides the same sharded trainer and must reproduce the
    single-device baseline bit-for-bit."""
    mesh = round_mesh(ndev)
    kw = dict(active_proportion=0.4, local_steps=3, local_batch=8,
              aggregation="cwmed", malicious_fraction=0.25, seed=0)
    bl1 = build_runtime(adapter, ds, dict(kw), baseline=True)
    bln = build_runtime(adapter, ds, dict(kw), baseline=True, mesh=mesh)
    bl1.run(2, eval_every=2)
    bln.run(2, eval_every=2)
    assert bl1.accuracies == bln.accuracies
    _leaves_allclose(bl1.params, bln.params)


def test_sharded_engine_shardings_and_stages(round_mesh, ds, adapter):
    """The sharded stages are what actually ran, and the arrays they
    produce carry the round-engine PartitionSpecs."""
    mesh = round_mesh(2)
    specs = round_engine_pspecs()
    rt = build_runtime(adapter, ds,
                       dict(CFG, quantize_chain=True, use_kernels=True),
                       mesh=mesh)
    from repro.fl import sharded as sharded_mod

    assert rt.pipeline.local_trainer is sharded_mod.train_local_sgd_sharded
    assert rt.pipeline.packer is sharded_mod.pack_top_k_int8_sharded
    assert rt.pipeline.aggregator is sharded_mod.aggregate_fused_int8_sharded
    assert isinstance(rt.pipeline.validator,
                      sharded_mod.ShardedCommitteeValidator)
    stack = jax.random.normal(jax.random.PRNGKey(0), (4, 4096))
    q, s = rt._sharded_quantize(stack)
    assert q.sharding.spec == specs["dshard"]
    assert s.sharding.spec == specs["dshard"]
    out = rt._sharded_agg(q, s, np.full((4,), 0.25, np.float32))
    assert out.sharding.spec == specs["dvec"]


def test_score_matrix_shardings(round_mesh, adapter):
    """The sharded score programs' outputs carry the score-matrix
    PartitionSpecs: the (P, Q) matrix is P-sharded over the data axis
    until the stage-boundary gather."""
    mesh = round_mesh(2)
    specs = score_matrix_pspecs()
    P = 4
    params, updates, vx, vy = _score_inputs(adapter, P)
    sharded = make_sharded_score_matrix_fn(adapter, mesh)
    scores = sharded(params, updates, vx, vy)
    assert scores.shape == (P, vx.shape[0])
    assert scores.sharding.spec == specs["scores"]

    from jax.flatten_util import ravel_pytree

    _, unravel = ravel_pytree(params)
    int8_sharded = make_sharded_score_from_int8_fn(adapter, mesh, unravel)
    scores8, q8, s8 = int8_sharded(params, updates, vx, vy)
    assert scores8.sharding.spec == specs["scores"]
    # the cached rows come back P-sharded alongside the scores
    assert q8.shape[0] == s8.shape[0] == P


def test_shard_ctx_tolerates_data_only_mesh(round_mesh):
    """make_shard_ctx on the round engine's 1-D ("data",) mesh: the model
    axis is absent -> size 1, and no spec may name it."""
    import jax.numpy as jnp

    from repro.models.shardctx import make_shard_ctx

    mesh = round_mesh(2)
    ctx = make_shard_ctx(mesh, ("data",), "model", batch_sharded=True,
                         num_kv_heads=8, num_heads=8)
    assert ctx.model_size == 1
    x = jnp.zeros((2, 4, 8))
    y = ctx.act(x)          # constraint applies on a model-axis-free mesh
    assert y.shape == x.shape
    assert ctx.q_spec is None  # heads can't shard without a model axis


def test_round_mesh_rejects_oversized_request():
    from repro.launch.mesh import make_round_mesh

    with pytest.raises(ValueError):
        make_round_mesh(len(jax.devices()) + 1)
