"""Differential + layout tests for the hierarchical (two-tier) round engine.

``repro.fl.hier`` must be a pure re-wiring of the flat pipeline:

* ``tiers=1`` short-circuits to the flat stage set — chain fingerprints and
  ``RoundLog``s must be IDENTICAL to a runtime built without the knob;
* a tiered round is deterministic in the device count: 1-, 2- and 8-device
  tiered runs (conftest forces 8 host devices) must produce bit-identical
  chains for BOTH the f32 and fused-int8 engines — sub-aggregate blobs come
  from row-local single-device kernels and the sharded scorers reproduce
  the single-device score matrices bit-for-bit (PR 3/4 invariants);
* the tiered chain layout (model + S sub-aggregate updates + tier-2
  committee block per round) is enforced and carries the audit record;
* streaming ingest holds the memory bound the subsystem exists for:
  ``peak_stack_bytes`` is bounded by one slice, not the O(P·D) flat stack;
* ``VirtualFederatedDataset`` presents P virtual clients over a small base
  without copying — the 100k-client bench substrate.
"""
import numpy as np
import pytest

from repro.api import build_runtime
from repro.core.blockchain import COMMITTEE, MODEL, UPDATE
from repro.data import VirtualFederatedDataset, make_femnist_like
from repro.fl import femnist_adapter

DEVICE_COUNTS = (1, 2, 8)
TIERS = 2

# 24 clients, everyone active: q_committee = 6, pool = 18 -> 2 slices of 9
# (3-member sub-committee + 6 trainers each)
HCFG = dict(active_proportion=1.0, committee_fraction=0.25, k_updates=4,
            local_steps=3, local_batch=8, malicious_fraction=0.25,
            attack_sigma=1.5, seed=0)


@pytest.fixture(scope="module")
def ds():
    return make_femnist_like(num_clients=24, mean_samples=40,
                             test_size=200, seed=3)


@pytest.fixture(scope="module")
def adapter():
    return femnist_adapter(width=8)


def _fingerprint(chain):
    return (
        chain.height,
        [b.hash for b in chain.blocks],
        [b.uploader for b in chain.blocks if b.kind == UPDATE],
    )


# ----------------------------------------------------------------------
# tiers=1 is the identity element of the knob
# ----------------------------------------------------------------------
def test_tiers_one_is_flat(ds, adapter):
    rt_flat = build_runtime(adapter, ds, dict(HCFG))
    rt_one = build_runtime(adapter, ds, dict(HCFG), tiers=1)
    logs_f = rt_flat.run(2, eval_every=2)
    logs_1 = rt_one.run(2, eval_every=2)
    assert _fingerprint(rt_flat.chain) == _fingerprint(rt_one.chain)
    assert logs_f == logs_1
    assert rt_one.hier_logs == []          # no tiered machinery ran
    assert not rt_one.chain.tier2


# ----------------------------------------------------------------------
# tiered rounds are bit-identical across device counts (f32 AND int8)
# ----------------------------------------------------------------------
def _tiered_cfg(engine):
    cfg = dict(HCFG)
    if engine == "int8":
        cfg.update(quantize_chain=True, use_kernels=True)
    return cfg


def _tiered_stages(engine, sharded):
    if engine != "int8":
        return None                        # default f32 inner validator
    # the fused score-from-int8 inner validator: exercises the row-quant
    # cache feeding the per-slice sub-aggregation
    return {"validator": "committee_int8_sharded" if sharded
            else "committee_int8"}


@pytest.mark.slow
@pytest.mark.parametrize("engine", ("f32", "int8"))
@pytest.mark.parametrize("ndev", DEVICE_COUNTS)
def test_tiered_round_parity_across_devices(round_mesh, ds, adapter,
                                            engine, ndev):
    cfg = _tiered_cfg(engine)
    rt1 = build_runtime(adapter, ds, dict(cfg), tiers=TIERS,
                        stages=_tiered_stages(engine, sharded=False))
    rtn = build_runtime(adapter, ds, dict(cfg), tiers=TIERS,
                        mesh=round_mesh(ndev),
                        stages=_tiered_stages(engine, sharded=True))
    logs1 = rt1.run(2, eval_every=2)
    logsn = rtn.run(2, eval_every=2)
    # sub-aggregate blobs are built by row-local kernels at single-device
    # width, so even the int8 chains must match hash-for-hash
    assert _fingerprint(rt1.chain) == _fingerprint(rtn.chain)
    assert logs1 == logsn
    assert rt1.committee == rtn.committee
    # peak_stack_bytes is legitimately device-dependent (the sharded
    # trainer pads slice rows to a device multiple); everything else in
    # the tiered accounting must match
    drop = "peak_stack_bytes"
    assert ([{k: v for k, v in l.items() if k != drop}
             for l in rt1.hier_logs]
            == [{k: v for k, v in l.items() if k != drop}
                for l in rtn.hier_logs])
    assert all(l[drop] < l["flat_stack_bytes"] for l in rtn.hier_logs)
    assert rt1.chain.verify() and rtn.chain.verify()


# ----------------------------------------------------------------------
# tiered chain layout + the committee audit block
# ----------------------------------------------------------------------
def test_tiered_chain_layout_and_committee_block(ds, adapter):
    rt = build_runtime(adapter, ds, dict(HCFG), tiers=TIERS)
    rt.run(2, eval_every=2)
    chain = rt.chain
    assert chain.tier2 and chain.k == TIERS
    assert chain.period == TIERS + 2
    assert chain.verify()
    # per round: model, S sub-aggregate updates, committee
    kinds = [b.kind for b in chain.blocks]
    round_kinds = [MODEL] + [UPDATE] * TIERS + [COMMITTEE]
    assert kinds == round_kinds * 2 + [MODEL]
    for t in range(2):
        rec = chain.committee_at_round(t)
        S = len(rec["uploaders"])
        assert S == TIERS
        assert rec["scores"].shape == (S, len(rec["members"]))
        assert rec["medians"].shape == (S,)
        assert rec["accepted"].dtype == bool
        assert list(rec["members"]) == sorted(rec["members"])
        # packed update blocks are the accepted sub-aggregates' reps
        uploaders = {b.uploader for b in chain.updates_at_round(t)}
        assert uploaders <= set(int(u) for u in rec["uploaders"])


def test_tiers_rejected_for_baselines(ds, adapter):
    with pytest.raises(ValueError, match="committee"):
        build_runtime(adapter, ds, dict(active_proportion=0.5),
                      baseline=True, tiers=2)


def test_too_many_tiers_for_pool(ds, adapter):
    # 24 active, q=6 -> pool of 18 can't feed 5 slices of >= 4 nodes
    rt = build_runtime(adapter, ds, dict(HCFG), tiers=5)
    with pytest.raises(ValueError, match="active non-committee"):
        rt.run(1, eval_every=2)


# ----------------------------------------------------------------------
# streaming ingest: the memory bound
# ----------------------------------------------------------------------
def test_streaming_peak_bounded_by_slice(ds, adapter):
    rt = build_runtime(adapter, ds, dict(HCFG), tiers=TIERS)
    rt.run(2, eval_every=2)
    assert len(rt.hier_logs) == 2
    for log in rt.hier_logs:
        assert log["tiers"] == TIERS
        # the flat engine would stack every trainer's update at once; the
        # tiered engine never holds more than one slice (+ the S
        # sub-aggregates at tier 2)
        assert 0 < log["peak_stack_bytes"] < log["flat_stack_bytes"]
        # peak ~ largest slice stack + tier-2 blocks, far under flat for
        # realistic S; with S=2 it must sit under ~3/4 of flat
        assert log["peak_stack_bytes"] < 0.75 * log["flat_stack_bytes"]


# ----------------------------------------------------------------------
# virtual dataset: the 100k-client substrate
# ----------------------------------------------------------------------
def test_virtual_dataset_aliases_base(ds):
    vds = VirtualFederatedDataset(ds, 60)
    assert vds.num_clients == 60
    assert len(vds.client_sizes()) == 60
    # cyclic aliasing, no copies
    assert vds.client_images[37] is ds.client_images[37 % 24]
    assert vds.client_labels[59] is ds.client_labels[59 % 24]
    assert vds.client_images[-1] is ds.client_images[59 % 24]
    with pytest.raises(IndexError):
        vds.client_images[60]
    np.testing.assert_array_equal(vds.test_images, ds.test_images)
    a, b = vds.merged_train()[0], ds.merged_train()[0]
    assert a.shape == b.shape


def test_tiered_round_over_virtual_clients(ds, adapter):
    vds = VirtualFederatedDataset(ds, 60)
    # 60 active, q = max(3, 60*0.25) = 15, pool of 45 -> 3 slices of 15
    rt = build_runtime(adapter, vds, dict(HCFG), tiers=3)
    logs = rt.run(1, eval_every=2)
    assert rt.chain.verify()
    assert logs[0].trainers > 0
    log = rt.hier_logs[0]
    assert log["tiers"] == 3
    assert log["peak_stack_bytes"] < log["flat_stack_bytes"]
