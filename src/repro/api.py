"""repro.api — one-call builders over the composable round pipeline.

The single entrypoint examples and benchmarks build through:

    from repro.api import build_runtime

    rt = build_runtime(adapter, dataset, {"active_proportion": 0.3})
    rt.run(rounds=10)

``cfg`` may be a ``BFLCConfig`` (-> ``BFLCRuntime``), an ``FLConfig``
(-> committee-free ``FLTrainer``), or a plain dict of config fields
(``baseline=True`` selects the FL baseline).  ``stages`` swaps any round
stage by registered name or bare callable — see ``repro.fl.pipeline``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Union

from repro.fl.baselines import FLConfig, FLTrainer
from repro.fl.runtime import BFLCConfig, BFLCRuntime

ConfigLike = Union[BFLCConfig, FLConfig, Dict[str, Any], None]


def build_config(cfg: ConfigLike = None, *, baseline: bool = False):
    """dict / None -> config dataclass; dataclasses pass through."""
    if cfg is None:
        cfg = {}
    if isinstance(cfg, dict):
        return FLConfig(**cfg) if baseline else BFLCConfig(**cfg)
    if isinstance(cfg, BFLCConfig):
        if baseline:
            raise ValueError(
                "baseline=True contradicts a BFLCConfig — pass an FLConfig "
                "(or a dict of FLConfig fields) for the committee-free "
                "baseline"
            )
        return cfg
    if isinstance(cfg, FLConfig):
        return cfg
    raise TypeError(
        f"cfg must be BFLCConfig, FLConfig, dict, or None — got {type(cfg)!r}"
    )


def build_runtime(
    adapter,
    dataset,
    cfg: ConfigLike = None,
    *,
    baseline: bool = False,
    initial_params=None,
    stages: Optional[Dict[str, object]] = None,
    mesh=None,
    tiers: Optional[int] = None,
    schedule: str = "sequential",
):
    """Builds the round runtime for a config.

    Returns ``BFLCRuntime`` (chain + committee consensus) for a
    ``BFLCConfig``, or ``FLTrainer`` (Basic FL / CwMed — same pipeline,
    committee stages as no-ops) for an ``FLConfig``/``baseline=True``.
    Both expose ``run(rounds, eval_every)``, ``run_round()``,
    ``evaluate()``, and per-round ``stage_timings``.

    ``mesh`` (e.g. ``repro.launch.mesh.make_round_mesh(8)``) selects the
    sharded multi-device round engine: local training AND committee
    validation are shard_mapped over the mesh's data axis
    (``local_sgd_sharded`` / ``committee_sharded`` — the P x Q score
    matrix is computed P-sharded and reproduces the single-device scores
    bit-for-bit), and with ``quantize_chain=True`` packing + aggregation
    run D-sharded (``top_k_int8_sharded`` / ``fused_int8_sharded``) and
    the fused score-from-int8 validators (``committee_int8`` /
    ``committee_int8_sharded``) become available.  ``stages`` still
    overrides any stage by name or callable.

    ``tiers=S`` (S > 1) selects the hierarchical two-tier round engine
    (``repro.fl.hier``): each round is partitioned into S sub-communities
    streamed through a slice-sized buffer, with a second-level committee
    round over the S sub-aggregates before the chain commit — peak
    update-stack memory is bounded by the largest slice.  A ``validator``
    entry in ``stages`` selects the tier-1 (per-slice) inner validator;
    ``tiers=1`` is the flat pipeline, bit-identical to omitting it.

    ``schedule="async"`` runs the same stage set under the asynchronous
    pipelined engine (``repro.fl.async_engine``): cohort t+1's local
    training is dispatched while cohort t's committee scoring / packing
    still runs host-side, with ``jax.block_until_ready`` only at true
    dependency edges.  Chain hashes and RoundLogs are bit-identical to
    ``schedule="sequential"`` (parity-gated); with ``tiers=S`` the S
    slices pipeline — slice s+1 trains while slice s sub-aggregates."""
    cfg = build_config(cfg, baseline=baseline)
    if tiers is not None:
        if isinstance(cfg, FLConfig):
            raise ValueError(
                "tiers applies to the BFLC committee runtime only — the "
                "committee-free baselines have no consensus to tier"
            )
        import dataclasses

        cfg = dataclasses.replace(cfg, tiers=int(tiers))
    if isinstance(cfg, FLConfig):
        return FLTrainer(adapter, dataset, cfg,
                         initial_params=initial_params, stages=stages,
                         mesh=mesh, schedule=schedule)
    return BFLCRuntime(adapter, dataset, cfg,
                       initial_params=initial_params, stages=stages,
                       mesh=mesh, schedule=schedule)
