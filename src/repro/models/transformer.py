"""The composable model stack: embedding -> scanned layer units -> head.

Three entry points:
  * ``forward``     — full-sequence, no cache (training).
  * ``prefill``     — full-sequence, returns logits + a filled decode cache.
  * ``decode_step`` — one token against the cache.

Layer units repeat ``num_units`` times; their parameters are stacked with a
leading unit axis and the forward pass ``lax.scan``s over them, keeping HLO
size independent of depth.  ``cfg.remat`` wraps the unit body in
``jax.checkpoint`` for training.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import mamba as mamba_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.attention import (
    attention_decode,
    attention_forward,
    init_attention,
)
from repro.models.cache import attn_cache_len, init_layer_cache
from repro.models.config import (
    MLP_DENSE,
    MLP_MOE,
    MLP_NONE,
    MLP_RWKV,
    LayerSpec,
    ModelConfig,
)
from repro.models.layers import (
    apply_conv_pos,
    apply_mlp,
    apply_norm,
    embed_init,
    init_conv_pos,
    init_mlp,
    init_norm,
)
from repro.models.moe import MoEShardingCtx, apply_moe, init_moe


class Batch(NamedTuple):
    """Model inputs.  Any of tokens/embeds may be None depending on frontend."""

    tokens: Optional[jnp.ndarray] = None        # (B,S) int32
    embeds: Optional[jnp.ndarray] = None        # (B,S,D)
    embed_mask: Optional[jnp.ndarray] = None    # (B,S) bool: use embeds here
    positions: Optional[jnp.ndarray] = None     # (B,S) or (3,B,S) int32
    targets: Optional[jnp.ndarray] = None       # (B,S) int32
    loss_mask: Optional[jnp.ndarray] = None     # (B,S) float32


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ----------------------------------------------------------------------------
# init
# ----------------------------------------------------------------------------


def init_layer(key, spec: LayerSpec, cfg: ModelConfig, dtype, virtual_r: int):
    km, kf = jax.random.split(key)
    p = {"norm1": init_norm(cfg, dtype)}
    if spec.mixer.startswith("attn"):
        p["mixer"] = init_attention(km, cfg, dtype)
    elif spec.mixer == "mamba":
        p["mixer"] = mamba_mod.init_mamba(km, cfg, dtype)
    elif spec.mixer == "rwkv6":
        p["mixer"] = rwkv_mod.init_rwkv_time_mix(km, cfg, dtype)
    else:
        raise ValueError(spec.mixer)
    if spec.mlp != MLP_NONE:
        p["norm2"] = init_norm(cfg, dtype)
    if spec.mlp == MLP_DENSE:
        p["mlp"] = init_mlp(kf, cfg, dtype)
    elif spec.mlp == MLP_MOE:
        p["mlp"] = init_moe(kf, cfg, dtype, virtual_r=virtual_r)
    elif spec.mlp == MLP_RWKV:
        p["mlp"] = rwkv_mod.init_rwkv_channel_mix(kf, cfg, dtype)
    return p


def init_model(key, cfg: ModelConfig, *, virtual_r: int = 1) -> dict:
    """Returns the full parameter pytree."""
    dtype = _dtype(cfg)
    k_embed, k_units, k_tail, k_head, k_extra, k_extra2 = jax.random.split(key, 6)
    params: dict = {}
    if cfg.frontend != "audio":
        params["embed"] = embed_init(k_embed, cfg.vocab_size, cfg.d_model, dtype=dtype)
    if cfg.frontend == "audio":
        params["mask_emb"] = (
            jax.random.normal(k_extra, (cfg.d_model,)) * 0.02
        ).astype(dtype)
        params["conv_pos"] = init_conv_pos(k_extra2, cfg, dtype)

    def init_unit(k):
        ks = jax.random.split(k, max(len(cfg.unit), 1))
        return tuple(
            init_layer(ks[i], spec, cfg, dtype, virtual_r)
            for i, spec in enumerate(cfg.unit)
        )

    unit_keys = jax.random.split(k_units, max(cfg.num_units, 1))
    if cfg.num_units:
        params["units"] = jax.vmap(init_unit)(unit_keys)
    tail_keys = jax.random.split(k_tail, max(len(cfg.tail), 1))
    params["tail"] = tuple(
        init_layer(tail_keys[i], spec, cfg, dtype, virtual_r)
        for i, spec in enumerate(cfg.tail)
    )
    params["final_norm"] = init_norm(cfg, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(k_head, cfg.vocab_size, cfg.d_model, dtype=dtype).T
    return params


# ----------------------------------------------------------------------------
# embedding / head
# ----------------------------------------------------------------------------


def embed_inputs(params, cfg: ModelConfig, batch: Batch) -> jnp.ndarray:
    if cfg.frontend == "audio":
        x = batch.embeds
        if batch.embed_mask is not None:
            # masked-prediction: replace masked frames with the mask embedding
            x = jnp.where(
                batch.embed_mask[..., None], params["mask_emb"][None, None], x
            )
        x = x + apply_conv_pos(params["conv_pos"], x)
        return x
    x = params["embed"][batch.tokens]                      # (B,S,D)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if batch.embeds is not None and batch.embed_mask is not None:
        # VLM: overwrite image-pad slots with projected patch embeddings
        x = jnp.where(batch.embed_mask[..., None], batch.embeds, x)
    return x


def lm_logits(params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    x = apply_norm(params["final_norm"], x, cfg)
    if cfg.tie_embeddings:
        return x @ params["embed"].T
    return x @ params["lm_head"]


# ----------------------------------------------------------------------------
# layer application
# ----------------------------------------------------------------------------


def apply_layer_forward(
    lp: dict,
    spec: LayerSpec,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ModelConfig,
    ctx: Optional[MoEShardingCtx],
    collect_cache: bool,
    max_len: int,
):
    """Returns (x, aux_loss, cache_entry_or_None)."""
    h = apply_norm(lp["norm1"], x, cfg)
    cache_entry = None
    if spec.mixer.startswith("attn"):
        if collect_cache:
            mixed, krot, vrot = attention_forward(
                lp["mixer"], h, positions, cfg, spec.mixer, return_kv=True,
                ctx=ctx,
            )
            cache_entry = _kv_to_cache(cfg, spec, krot, vrot, positions, max_len)
        else:
            mixed = attention_forward(lp["mixer"], h, positions, cfg,
                                      spec.mixer, ctx=ctx)
    elif spec.mixer == "mamba":
        mixed, state = mamba_mod.mamba_forward(lp["mixer"], h, cfg)
        if collect_cache:
            cache_entry = state
    elif spec.mixer == "rwkv6":
        mixed, tm_state = rwkv_mod.rwkv_time_mix_forward(lp["mixer"], h, cfg)
        if collect_cache:
            cache_entry = {"tm": tm_state}
    else:
        raise ValueError(spec.mixer)
    x = x + mixed

    aux = jnp.zeros((), jnp.float32)
    if spec.mlp != MLP_NONE:
        h2 = apply_norm(lp["norm2"], x, cfg)
        if spec.mlp == MLP_DENSE:
            x = x + apply_mlp(lp["mlp"], h2, cfg)
        elif spec.mlp == MLP_MOE:
            y, aux = apply_moe(lp["mlp"], h2, cfg, getattr(ctx, "moe", ctx))
            x = x + y
        elif spec.mlp == MLP_RWKV:
            y, cm_state = rwkv_mod.rwkv_channel_mix_forward(lp["mlp"], h2, cfg)
            x = x + y
            if collect_cache and cache_entry is not None:
                cache_entry["cm"] = cm_state
    return x, aux, cache_entry


def _kv_to_cache(cfg, spec, k, v, positions, max_len):
    """Pack prefill K/V (B,S,Kv,hd) into a decode cache entry."""
    B, S = k.shape[0], k.shape[1]
    L = attn_cache_len(cfg, spec.mixer, max_len)
    pos2d = positions[0] if positions.ndim == 3 else positions
    if S >= L:
        # keep the last L tokens; ring-buffer slot = pos % L
        k_keep, v_keep, p_keep = k[:, S - L :], v[:, S - L :], pos2d[:, S - L :]
        slots = p_keep % L
        b_idx = jnp.arange(B)[:, None]
        ck = jnp.zeros((B, L) + k.shape[2:], k.dtype).at[b_idx, slots].set(k_keep)
        cv = jnp.zeros((B, L) + v.shape[2:], v.dtype).at[b_idx, slots].set(v_keep)
        cp = jnp.full((B, L), -1, jnp.int32).at[b_idx, slots].set(p_keep)
        return {"k": ck, "v": cv, "pos": cp}
    pad = L - S
    ck = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    cv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    cp = jnp.pad(pos2d, ((0, 0), (0, pad)), constant_values=-1)
    return {"k": ck, "v": cv, "pos": cp}


def apply_layer_decode(
    lp: dict,
    spec: LayerSpec,
    x: jnp.ndarray,            # (B,1,D)
    position: jnp.ndarray,     # (B,)
    cache: dict,
    cfg: ModelConfig,
    ctx: Optional[MoEShardingCtx],
    mrope_position: Optional[jnp.ndarray],
):
    h = apply_norm(lp["norm1"], x, cfg)
    if spec.mixer.startswith("attn"):
        mixed, ck, cv, cp = attention_decode(
            lp["mixer"], h, position, cache["k"], cache["v"], cache["pos"],
            cfg, spec.mixer, mrope_position=mrope_position,
        )
        new_cache = {"k": ck, "v": cv, "pos": cp}
    elif spec.mixer == "mamba":
        mixed, new_cache = mamba_mod.mamba_step(lp["mixer"], h, cfg, cache)
    elif spec.mixer == "rwkv6":
        mixed, tm = rwkv_mod.rwkv_time_mix_step(lp["mixer"], h, cfg, cache["tm"])
        new_cache = dict(cache, tm=tm)
    else:
        raise ValueError(spec.mixer)
    x = x + mixed
    if spec.mlp != MLP_NONE:
        h2 = apply_norm(lp["norm2"], x, cfg)
        if spec.mlp == MLP_DENSE:
            x = x + apply_mlp(lp["mlp"], h2, cfg)
        elif spec.mlp == MLP_MOE:
            y, _ = apply_moe(lp["mlp"], h2, cfg, getattr(ctx, "moe", ctx))
            x = x + y
        elif spec.mlp == MLP_RWKV:
            y, cm = rwkv_mod.rwkv_channel_mix_forward(
                lp["mlp"], h2, cfg, state=new_cache.get("cm")
            )
            x = x + y
            new_cache["cm"] = cm
    return x, new_cache


# ----------------------------------------------------------------------------
# full model
# ----------------------------------------------------------------------------


def _unit_forward(unit_params, x, positions, cfg, ctx, collect_cache, max_len):
    aux_total = jnp.zeros((), jnp.float32)
    caches = []

    def layer_fn(lp, spec, xin):
        xo, aux, ce = apply_layer_forward(
            lp, spec, xin, positions, cfg, ctx, collect_cache, max_len
        )
        if hasattr(ctx, "act"):
            xo = ctx.act(xo)
        return xo, aux, ce

    for i, spec in enumerate(cfg.unit):
        fn = layer_fn
        if cfg.remat == "layer" and not collect_cache:
            # per-layer checkpoint: the unit backward re-materializes one
            # layer's internals at a time instead of the whole unit's
            # (EXPERIMENTS.md §Perf H3 — 8-layer Jamba units OOM otherwise)
            fn = jax.checkpoint(layer_fn, static_argnums=(1,))
        x, aux, ce = fn(unit_params[i], spec, x)
        aux_total = aux_total + aux
        caches.append(ce)
    return x, aux_total, tuple(caches)


def _stack_forward(params, cfg, x, positions, ctx, collect_cache, max_len):
    """Scan over units, then run the tail layers."""
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.num_units:
        def body(carry, unit_params):
            xc, auxc = carry
            xo, aux, caches = _unit_forward(
                unit_params, xc, positions, cfg, ctx, collect_cache, max_len
            )
            return (xo, auxc + aux), caches

        if cfg.remat:
            # outer unit checkpoint always; with remat == "layer" the inner
            # per-layer checkpoints bound the re-backward's working set
            body = jax.checkpoint(body)
        (x, aux_total), unit_caches = jax.lax.scan(
            body, (x, aux_total), params["units"]
        )
    else:
        unit_caches = ()

    tail_caches = []
    for i, spec in enumerate(cfg.tail):
        x, aux, ce = apply_layer_forward(
            params["tail"][i], spec, x, positions, cfg, ctx, collect_cache, max_len
        )
        aux_total = aux_total + aux
        tail_caches.append(ce)
    return x, aux_total, unit_caches, tuple(tail_caches)


def forward(params, cfg: ModelConfig, batch: Batch, ctx=None):
    """Training forward: returns (logits, aux_loss)."""
    x = embed_inputs(params, cfg, batch)
    if hasattr(ctx, "act"):
        x = ctx.act(x)
    positions = batch.positions
    if positions is None:
        B, S = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x, aux, _, _ = _stack_forward(params, cfg, x, positions, ctx, False, 0)
    logits = lm_logits(params, cfg, x)
    if hasattr(ctx, "logits"):
        logits = ctx.logits(logits)
    return logits, aux


def prefill(params, cfg: ModelConfig, batch: Batch, max_len: int, ctx=None):
    """Prefill: returns (logits_last, cache) with the cache filled."""
    x = embed_inputs(params, cfg, batch)
    positions = batch.positions
    if positions is None:
        B, S = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if hasattr(ctx, "act"):
        x = ctx.act(x)
    x, aux, unit_caches, tail_caches = _stack_forward(
        params, cfg, x, positions, ctx, True, max_len
    )
    logits = lm_logits(params, cfg, x[:, -1:])
    if hasattr(ctx, "logits"):
        logits = ctx.logits(logits)
    return logits, {"units": unit_caches, "tail": tail_caches}


def decode_step(
    params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,        # (B,1) int32
    position: jnp.ndarray,      # (B,) int32
    cache: dict,
    ctx=None,
    mrope_position: Optional[jnp.ndarray] = None,   # (3,B,1)
    embeds: Optional[jnp.ndarray] = None,           # (B,1,D) frontend decode
):
    """One decode step: returns (logits (B,1,V), new_cache)."""
    if cfg.frontend == "audio":
        raise ValueError("encoder-only architectures have no decode step")
    x = params["embed"][tokens]
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if embeds is not None:
        x = embeds

    if cfg.num_units:
        def body(xc, scanned):
            unit_params, unit_cache = scanned
            new_caches = []
            for i, spec in enumerate(cfg.unit):
                xc, nc = apply_layer_decode(
                    unit_params[i], spec, xc, position, unit_cache[i], cfg, ctx,
                    mrope_position,
                )
                if hasattr(ctx, "act"):
                    xc = ctx.act(xc)
                new_caches.append(nc)
            return xc, tuple(new_caches)

        x, new_unit_caches = jax.lax.scan(
            body, x, (params["units"], cache["units"])
        )
    else:
        new_unit_caches = ()

    new_tail = []
    for i, spec in enumerate(cfg.tail):
        x, nc = apply_layer_decode(
            params["tail"][i], spec, x, position, cache["tail"][i], cfg, ctx,
            mrope_position,
        )
        new_tail.append(nc)
    logits = lm_logits(params, cfg, x)
    if hasattr(ctx, "logits"):
        logits = ctx.logits(logits)
    return logits, {"units": new_unit_caches, "tail": tuple(new_tail)}
