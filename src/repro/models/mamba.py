"""Mamba-1 selective SSM block (as used inside Jamba).

Training/prefill runs a *chunked* scan: an outer ``lax.scan`` over chunks of
``CHUNK`` tokens (rematerialized, so backward keeps only per-chunk states)
with an inner exact sequential scan.  Decode is the exact single-step
recurrence with a (conv_state, ssm_state) cache.

Recurrence (per channel c of d_inner, per state dim n of d_state):
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * u_t
    y_t = C_t . h_t + D_param * u_t
with input-dependent dt (softplus), B, C (Jamba applies RMSNorm to dt/B/C
before projection).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init

CHUNK = 64


def init_mamba(key, cfg: ModelConfig, dtype) -> dict:
    D = cfg.d_model
    din = cfg.mamba_d_inner
    ds = cfg.mamba_d_state
    dc = cfg.mamba_d_conv
    dtr = cfg.resolved_dt_rank
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A
    a = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (din, 1))
    dt_init_std = dtr ** -0.5
    return {
        "in_proj": dense_init(ks[0], D, 2 * din, dtype=dtype),
        "conv_w": (
            jax.random.normal(ks[1], (dc, din)) / math.sqrt(dc)
        ).astype(dtype),
        "conv_b": jnp.zeros((din,), dtype),
        "x_proj": dense_init(ks[2], din, dtr + 2 * ds, dtype=dtype),
        "dt_proj": (
            jax.random.uniform(ks[3], (dtr, din), minval=-dt_init_std,
                               maxval=dt_init_std)
        ).astype(dtype),
        "dt_bias": jnp.full((din,), -4.6, dtype),  # softplus^-1(0.01)
        "A_log": jnp.log(a).astype(jnp.float32),
        "D": jnp.ones((din,), dtype),
        "out_proj": dense_init(ks[4], din, D, dtype=dtype),
        # Jamba-style RMSNorms on dt / B / C
        "dt_norm": jnp.ones((dtr,), dtype),
        "b_norm": jnp.ones((ds,), dtype),
        "c_norm": jnp.ones((ds,), dtype),
    }


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(
        x.dtype
    )


def _ssm_inputs(params, u, cfg: ModelConfig):
    """u: (B,S,din) post-conv activations -> (dt, Bmat, Cmat) in f32."""
    ds = cfg.mamba_d_state
    dtr = cfg.resolved_dt_rank
    proj = u @ params["x_proj"]                            # (B,S,dtr+2ds)
    dt_lowrank = _rms(proj[..., :dtr], params["dt_norm"])
    Bmat = _rms(proj[..., dtr : dtr + ds], params["b_norm"]).astype(jnp.float32)
    Cmat = _rms(proj[..., dtr + ds :], params["c_norm"]).astype(jnp.float32)
    dt = jax.nn.softplus(
        (dt_lowrank @ params["dt_proj"]).astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32)
    )                                                      # (B,S,din)
    return dt, Bmat, Cmat


def _ssm_step(h, inp, A):
    """h: (B,din,ds); inp = (u_t (B,din), dt_t (B,din), B_t (B,ds), C_t (B,ds))."""
    u_t, dt_t, B_t, C_t = inp
    da = jnp.exp(dt_t[..., None] * A[None])                # (B,din,ds)
    dbu = (dt_t * u_t)[..., None] * B_t[:, None, :]        # (B,din,ds)
    h = da * h + dbu
    y = jnp.einsum("bdn,bn->bd", h, C_t)
    return h, y


def _scan_chunk(params_A, h0, u, dt, Bm, Cm):
    """Exact inner scan over a chunk.  u,dt: (B,L,din); Bm,Cm: (B,L,ds)."""
    def step(h, xs):
        return _ssm_step(h, xs, params_A)

    xs = (
        u.swapaxes(0, 1),
        dt.swapaxes(0, 1),
        Bm.swapaxes(0, 1),
        Cm.swapaxes(0, 1),
    )
    h, ys = jax.lax.scan(step, h0, xs)
    return h, ys.swapaxes(0, 1)                            # (B,L,din)


def mamba_forward(params, x, cfg: ModelConfig, state=None):
    """x: (B,S,D) -> (out, new_state).

    state: None or dict(conv (B,dc-1,din), ssm (B,din,ds))."""
    B, S, D = x.shape
    din = cfg.mamba_d_inner
    ds = cfg.mamba_d_state
    dc = cfg.mamba_d_conv

    xz = x @ params["in_proj"]
    u, z = jnp.split(xz, 2, axis=-1)                       # (B,S,din) each

    conv_prev = (
        state["conv"] if state else jnp.zeros((B, dc - 1, din), x.dtype)
    )
    ssm_prev = (
        state["ssm"] if state else jnp.zeros((B, din, ds), jnp.float32)
    )
    # causal depthwise conv over time
    u_pad = jnp.concatenate([conv_prev, u], axis=1)        # (B,S+dc-1,din)
    conv = sum(
        u_pad[:, i : i + S, :] * params["conv_w"][i][None, None]
        for i in range(dc)
    )
    u_act = jax.nn.silu(conv + params["conv_b"]).astype(jnp.float32)

    dt, Bm, Cm = _ssm_inputs(params, u_act.astype(x.dtype), cfg)
    A = -jnp.exp(params["A_log"])                          # (din,ds)

    pad = (-S) % CHUNK
    if pad:
        padt = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
        u_act_p, dt_p, Bm_p, Cm_p = map(padt, (u_act, dt, Bm, Cm))
    else:
        u_act_p, dt_p, Bm_p, Cm_p = u_act, dt, Bm, Cm
    n = u_act_p.shape[1] // CHUNK

    reshape = lambda t: t.reshape(B, n, CHUNK, t.shape[-1]).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_body(h, xs):
        uc, dtc, bc, cc = xs
        return _scan_chunk(A, h, uc, dtc, bc, cc)

    h_final, ys = jax.lax.scan(
        chunk_body,
        ssm_prev,
        (reshape(u_act_p), reshape(dt_p), reshape(Bm_p), reshape(Cm_p)),
    )
    y = ys.swapaxes(0, 1).reshape(B, n * CHUNK, din)[:, :S]
    y = y + u_act * params["D"].astype(jnp.float32)
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ params["out_proj"]

    # note: with padding, h_final includes pad steps where dt=0 -> exp(0)=1,
    # dbu=0 -> state unchanged.  (softplus(0 @ W + bias) != 0, but u_pad=0
    # makes dbu=0; da = exp(dt*A) < 1 decays state slightly on pad steps —
    # acceptable for smoke shapes; production shapes are CHUNK-aligned.)
    new_state = {
        "conv": u_pad[:, S : S + dc - 1, :] if dc > 1 else conv_prev,
        "ssm": h_final,
    }
    return out, new_state


def mamba_step(params, x, cfg: ModelConfig, state):
    """Single-token decode.  x: (B,1,D)."""
    B, _, D = x.shape
    din = cfg.mamba_d_inner
    dc = cfg.mamba_d_conv

    xz = x[:, 0] @ params["in_proj"]
    u, z = jnp.split(xz, 2, axis=-1)                       # (B,din)

    conv_prev = state["conv"]                              # (B,dc-1,din)
    window = jnp.concatenate([conv_prev, u[:, None]], axis=1)  # (B,dc,din)
    conv = jnp.einsum("bcd,cd->bd", window, params["conv_w"])
    u_act = jax.nn.silu(conv + params["conv_b"]).astype(jnp.float32)

    dt, Bm, Cm = _ssm_inputs(params, u_act[:, None].astype(x.dtype), cfg)
    A = -jnp.exp(params["A_log"])
    h, y = _ssm_step(state["ssm"], (u_act, dt[:, 0], Bm[:, 0], Cm[:, 0]), A)
    y = y + u_act * params["D"].astype(jnp.float32)
    out = (y.astype(x.dtype) * jax.nn.silu(z))[:, None] @ params["out_proj"]
    return out, {"conv": window[:, 1:], "ssm": h}


def init_mamba_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    return {
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, cfg.mamba_d_inner), dtype),
        "ssm": jnp.zeros(
            (batch, cfg.mamba_d_inner, cfg.mamba_d_state), jnp.float32
        ),
    }
