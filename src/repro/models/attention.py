"""Grouped-query attention with RoPE / M-RoPE, causal, bidirectional and
sliding-window masking, plus a KV cache for decode.

Two execution paths:

* ``_dense_attention``  — materializes (S_q, S_kv) scores; used for short
  sequences (<= DENSE_MAX) and single-token decode.
* ``_chunked_attention`` — flash-style online-softmax over KV blocks via
  ``lax.scan`` (outer scan over Q blocks, inner over KV blocks).  Never
  materializes more than (q_block, kv_block) scores, so 32k prefill and the
  500k decode cache fit in the dry-run memory analysis.  The inner scan
  computes the full rectangle and masks — i.e. causal block skipping is NOT
  done in the baseline; see EXPERIMENTS.md §Perf where this is one of the
  hillclimb levers.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import (
    ATTN,
    ATTN_GLOBAL,
    ATTN_LOCAL,
    ATTN_SWA,
    ModelConfig,
)
from repro.models.layers import apply_mrope, apply_rope, dense_init

DENSE_MAX = 2048     # max sequence length for the dense path
Q_BLOCK = 512
KV_BLOCK = 512

NEG_INF = -1e30


def is_windowed(mixer: str) -> bool:
    return mixer in (ATTN_SWA, ATTN_LOCAL)


# ----------------------------------------------------------------------------
# params
# ----------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, dtype) -> dict:
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, cfg.d_model, cfg.num_heads * hd, dtype=dtype),
        "wk": dense_init(kk, cfg.d_model, cfg.num_kv_heads * hd, dtype=dtype),
        "wv": dense_init(kv, cfg.d_model, cfg.num_kv_heads * hd, dtype=dtype),
        "wo": dense_init(ko, cfg.num_heads * hd, cfg.d_model, dtype=dtype),
    }
    if cfg.attention_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
    return p


# ----------------------------------------------------------------------------
# masking
# ----------------------------------------------------------------------------


def _pair_mask(
    q_pos: jnp.ndarray,   # (..., Sq)
    kv_pos: jnp.ndarray,  # (..., Skv)  (absolute positions; -1 = invalid slot)
    *,
    causal: bool,
    window: int,
) -> jnp.ndarray:
    """Boolean (..., Sq, Skv) mask — True where attention is allowed."""
    q = q_pos[..., :, None]
    k = kv_pos[..., None, :]
    ok = k >= 0
    if causal:
        ok = ok & (k <= q)
    if window > 0:
        ok = ok & (q - k < window)
    return ok


# ----------------------------------------------------------------------------
# core attention computations
# ----------------------------------------------------------------------------


def _dense_attention(q, k, v, mask, softcap: float) -> jnp.ndarray:
    """q: (B,Sq,H,Dh); k,v: (B,Skv,Kv,Dh); mask: (B,Sq,Skv) bool."""
    B, Sq, H, Dh = q.shape
    Kv = k.shape[2]
    G = H // Kv
    qf = q.astype(jnp.float32) * (Dh ** -0.5)
    qg = qf.reshape(B, Sq, Kv, G, Dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    if softcap > 0:
        scores = jnp.tanh(scores / softcap) * softcap
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, Dh).astype(q.dtype)


def _chunked_attention(
    q, k, v, q_pos, kv_pos, *, causal: bool, window: int, softcap: float
) -> jnp.ndarray:
    """Flash-style attention: outer scan over Q blocks, inner over KV blocks.

    q: (B,Sq,H,Dh), k/v: (B,Skv,Kv,Dh).  Sq % Q_BLOCK == 0, Skv % KV_BLOCK == 0
    (callers pad).  q_pos: (B,Sq), kv_pos: (B,Skv).
    """
    B, Sq, H, Dh = q.shape
    Skv, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    nq, nk = Sq // Q_BLOCK, Skv // KV_BLOCK

    qf = (q.astype(jnp.float32) * (Dh ** -0.5)).reshape(B, nq, Q_BLOCK, Kv, G, Dh)
    kf = k.astype(jnp.float32).reshape(B, nk, KV_BLOCK, Kv, Dh)
    vf = v.astype(jnp.float32).reshape(B, nk, KV_BLOCK, Kv, Dh)
    qp = q_pos.reshape(B, nq, Q_BLOCK)
    kp = kv_pos.reshape(B, nk, KV_BLOCK)

    def q_block_body(_, qi):
        qb, qpb = qi            # (B,QB,Kv,G,Dh), (B,QB)

        def kv_body(carry, ki):
            m, l, acc = carry
            kb, vb, kpb = ki    # (B,KB,Kv,Dh), (B,KB,Kv,Dh), (B,KB)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb)  # (B,Kv,G,QB,KB)
            if softcap > 0:
                s = jnp.tanh(s / softcap) * softcap
            mask = _pair_mask(qpb, kpb, causal=causal, window=window)
            s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            scale = jnp.exp(m - m_new)
            l_new = l * scale + p.sum(axis=-1)
            acc_new = acc * scale[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, vb
            )
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((B, Kv, G, Q_BLOCK), NEG_INF, jnp.float32),
            jnp.zeros((B, Kv, G, Q_BLOCK), jnp.float32),
            jnp.zeros((B, Kv, G, Q_BLOCK, Dh), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(
            kv_body,
            init,
            (
                jnp.moveaxis(kf, 1, 0),
                jnp.moveaxis(vf, 1, 0),
                jnp.moveaxis(kp, 1, 0),
            ),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)   # (B,Kv,G,QB,Dh)
        return None, out

    _, outs = jax.lax.scan(
        q_block_body,
        None,
        (jnp.moveaxis(qf, 1, 0), jnp.moveaxis(qp, 1, 0)),
    )
    # outs: (nq, B, Kv, G, QB, Dh) -> (B, Sq, H, Dh)
    out = jnp.moveaxis(outs, 0, 1).transpose(0, 1, 4, 2, 3, 5)
    return out.reshape(B, Sq, H, Dh).astype(q.dtype)


# ----------------------------------------------------------------------------
# public entry points
# ----------------------------------------------------------------------------


def _project_qkv(params, x, cfg: ModelConfig):
    hd = cfg.resolved_head_dim
    B, S, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    return (
        q.reshape(B, S, cfg.num_heads, hd),
        k.reshape(B, S, cfg.num_kv_heads, hd),
        v.reshape(B, S, cfg.num_kv_heads, hd),
    )


def _rotate(x, positions, cfg: ModelConfig):
    if cfg.rope == "none":
        return x
    if cfg.rope == "mrope":
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    if positions.ndim == 3:  # m-rope style positions on a standard-rope model
        positions = positions[0]
    return apply_rope(x, positions, cfg.rope_theta)


def attention_forward(
    params: dict,
    x: jnp.ndarray,          # (B,S,D)
    positions: jnp.ndarray,  # (B,S) or (3,B,S)
    cfg: ModelConfig,
    mixer: str,
    return_kv: bool = False,
    ctx=None,
):
    """Full-sequence attention (training / prefill, no cache).

    With ``return_kv=True`` also returns the rotated K and V (for prefill
    cache construction)."""
    q, k, v = _project_qkv(params, x, cfg)
    q = _rotate(q, positions, cfg)
    k = _rotate(k, positions, cfg)
    if ctx is not None and hasattr(ctx, "kv"):
        # head-shard Q/K/V when head counts divide the model axis
        q = ctx.q(q)
        k = ctx.kv(k)
        v = ctx.kv(v)
    pos2d = positions[0] if positions.ndim == 3 else positions
    causal = cfg.causal
    window = cfg.sliding_window if is_windowed(mixer) else 0
    S = x.shape[1]
    if S <= DENSE_MAX:
        mask = _pair_mask(pos2d, pos2d, causal=causal, window=window)
        out = _dense_attention(q, k, v, mask, cfg.attn_logit_softcap)
    else:
        assert cfg.attn_logit_softcap == 0, "flash path has no softcap"
        from jax.sharding import PartitionSpec as P

        from repro.models.flash import flash_attention, pick_q_block

        # Expand KV to the full H heads: a single fused head dim carries the
        # model-axis sharding cleanly through every flash einsum.  With the
        # grouped (Kv, G) layout GSPMD cannot express 16-way head sharding
        # across the two split dims and all-gathers the (QB, KB) score blocks
        # in the backward (observed 3.3 TB/device on qwen3 train_4k).
        G = cfg.num_heads // cfg.num_kv_heads
        k_e = jnp.repeat(k, G, axis=2) if G > 1 else k
        v_e = jnp.repeat(v, G, axis=2) if G > 1 else v
        if ctx is not None and hasattr(ctx, "q"):
            k_e = ctx.q(k_e)
            v_e = ctx.q(v_e)
        # block_spec over canonical (B, nq, Kv, G, QB, ...) — see flash.py
        q_block, block_spec, mesh = 512, None, None
        if ctx is not None and getattr(ctx, "model_size", 1) > 1:
            mesh = ctx.mesh
            if ctx.q_spec is not None:     # H % mesh == 0: shard heads
                block_spec = P(ctx.dp, None, ctx.model_axis, None, None, None)
            else:                          # shard the q-block dim instead
                q_block = pick_q_block(S, ctx.model_size)
                block_spec = P(ctx.dp, ctx.model_axis, None, None, None, None)
        out = flash_attention(
            q, k_e, v_e, pos2d, pos2d, causal, window, q_block,
            block_spec, mesh,
        )
    B, Sq = out.shape[0], out.shape[1]
    out = out.reshape(B, Sq, -1) @ params["wo"]
    if return_kv:
        return out, k, v
    return out


def attention_decode(
    params: dict,
    x: jnp.ndarray,            # (B,1,D)
    position: jnp.ndarray,     # (B,) int32 absolute position of the new token
    cache_k: jnp.ndarray,      # (B,Sc,Kv,Dh)  rotated keys
    cache_v: jnp.ndarray,      # (B,Sc,Kv,Dh)
    cache_pos: jnp.ndarray,    # (B,Sc) absolute position per slot (-1 invalid)
    cfg: ModelConfig,
    mixer: str,
    mrope_position: Optional[jnp.ndarray] = None,   # (3,B,1) for mrope
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Single-token decode against a (possibly ring-buffer) KV cache.

    Returns (out, new_cache_k, new_cache_v, new_cache_pos).
    Keys are stored rotated, so the cache never needs re-rotation.
    Sliding-window layers use a ring buffer: slot = position % window.
    """
    q, k, v = _project_qkv(params, x, cfg)
    if cfg.rope == "mrope":
        rp = (
            mrope_position
            if mrope_position is not None
            else jnp.broadcast_to(position[None, :, None], (3,) + position.shape + (1,))
        )
        q = apply_mrope(q, rp, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, rp, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.rope != "none":
        q = apply_rope(q, position[:, None], cfg.rope_theta)
        k = apply_rope(k, position[:, None], cfg.rope_theta)

    Sc = cache_k.shape[1]
    window = cfg.sliding_window if is_windowed(mixer) else 0
    # Ring-buffer slot.  For full-attention layers Sc == max_len so this is
    # just ``position``; for windowed layers it wraps around the window.
    slot = position % Sc

    # write the new K/V/pos into the per-batch slot
    b_idx = jnp.arange(x.shape[0])
    cache_k = cache_k.at[b_idx, slot].set(k[:, 0])
    cache_v = cache_v.at[b_idx, slot].set(v[:, 0])
    cache_pos = cache_pos.at[b_idx, slot].set(position)

    q_pos = position[:, None]                       # (B,1)
    # q_len == 1: dense attention is O(B*H*Skv) — no S^2 blowup — and the
    # softmax reduction over a seq-sharded cache lowers to small psums
    # (a blocked scan would dynamic-slice the sharded seq axis and force XLA
    # to replicate the whole cache per step).
    mask = _pair_mask(q_pos, cache_pos, causal=cfg.causal, window=window)
    out = _dense_attention(q, cache_k, cache_v, mask, cfg.attn_logit_softcap)
    B = out.shape[0]
    out = out.reshape(B, 1, -1) @ params["wo"]
    return out, cache_k, cache_v, cache_pos
