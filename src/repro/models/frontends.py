"""Stub modality frontends (the one sanctioned carve-out, DESIGN.md §5).

The audio (HuBERT) conv feature extractor and the VLM (Qwen2-VL) ViT encoder
are NOT implemented; these stubs produce frame/patch embeddings with the
correct shapes, dtypes and position semantics so the transformer backbone —
which IS fully implemented — consumes exactly what the real frontend would
hand it.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import Batch


# ----------------------------------------------------------------------------
# audio (HuBERT): 20 ms frames -> frame embeddings + masked-prediction targets
# ----------------------------------------------------------------------------


def hubert_batch(
    key, cfg: ModelConfig, batch: int, frames: int, *, mask_prob: float = 0.08,
    mask_span: int = 10,
) -> Batch:
    """Synthesizes a HuBERT masked-prediction training batch.

    ``embeds`` stand in for the conv-feature-extractor output; ``targets``
    are k-means cluster ids in [0, vocab); ``embed_mask`` marks masked frames
    (loss is computed only there, mirroring HuBERT's masked loss)."""
    k1, k2, k3 = jax.random.split(key, 3)
    embeds = jax.random.normal(k1, (batch, frames, cfg.d_model)).astype(
        jnp.dtype(cfg.dtype)
    )
    targets = jax.random.randint(k2, (batch, frames), 0, cfg.vocab_size)
    # span masking: choose start frames, extend mask_span
    starts = jax.random.bernoulli(k3, mask_prob, (batch, frames))
    mask = jnp.zeros((batch, frames), bool)
    for off in range(mask_span):
        mask = mask | jnp.roll(starts, off, axis=1)
    positions = jnp.broadcast_to(
        jnp.arange(frames, dtype=jnp.int32)[None], (batch, frames)
    )
    return Batch(
        tokens=None,
        embeds=embeds,
        embed_mask=mask,
        positions=positions,
        targets=targets,
        loss_mask=mask.astype(jnp.float32),
    )


# ----------------------------------------------------------------------------
# vision (Qwen2-VL): dynamic-resolution patches + M-RoPE position streams
# ----------------------------------------------------------------------------


def mrope_positions_for_image(
    text_len_before: int, grid_h: int, grid_w: int, text_len_after: int
) -> jnp.ndarray:
    """Builds the (3, S) M-RoPE position streams for [text, image, text].

    Text tokens advance all three streams together; image patches share one
    temporal position while the h/w streams trace the patch grid — the
    Qwen2-VL scheme."""
    parts_t, parts_h, parts_w = [], [], []
    t = jnp.arange(text_len_before, dtype=jnp.int32)
    parts_t.append(t); parts_h.append(t); parts_w.append(t)
    base = text_len_before
    hh, ww = jnp.meshgrid(
        jnp.arange(grid_h, dtype=jnp.int32),
        jnp.arange(grid_w, dtype=jnp.int32),
        indexing="ij",
    )
    n_img = grid_h * grid_w
    parts_t.append(jnp.full((n_img,), base, jnp.int32))
    parts_h.append(base + hh.reshape(-1))
    parts_w.append(base + ww.reshape(-1))
    after_start = base + max(grid_h, grid_w)
    a = after_start + jnp.arange(text_len_after, dtype=jnp.int32)
    parts_t.append(a); parts_h.append(a); parts_w.append(a)
    return jnp.stack(
        [jnp.concatenate(p) for p in (parts_t, parts_h, parts_w)]
    )                                                      # (3, S)


def vlm_batch(
    key, cfg: ModelConfig, batch: int, seq: int, *, image_patches: int = 0,
    grid: Tuple[int, int] = (0, 0),
) -> Batch:
    """Synthesizes a Qwen2-VL-style mixed text+image training batch.

    ``embeds`` stand in for ViT->projector patch embeddings placed where
    ``embed_mask`` is True; the rest are text tokens."""
    k1, k2, k3 = jax.random.split(key, 3)
    dtype = jnp.dtype(cfg.dtype)
    tokens = jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size)
    if image_patches:
        gh, gw = grid
        assert gh * gw == image_patches
        text_before = max(1, (seq - image_patches) // 2)
        text_after = seq - image_patches - text_before
        pos = mrope_positions_for_image(text_before, gh, gw, text_after)
        positions = jnp.broadcast_to(pos[:, None, :], (3, batch, seq))
        emask = jnp.zeros((seq,), bool).at[
            text_before : text_before + image_patches
        ].set(True)
        embed_mask = jnp.broadcast_to(emask[None], (batch, seq))
        embeds = jax.random.normal(k2, (batch, seq, cfg.d_model)).astype(dtype)
    else:
        p = jnp.arange(seq, dtype=jnp.int32)
        positions = jnp.broadcast_to(p[None, None], (3, batch, seq))
        embed_mask = jnp.zeros((batch, seq), bool)
        embeds = jnp.zeros((batch, seq, cfg.d_model), dtype)
    targets = jnp.roll(tokens, -1, axis=1)
    loss_mask = jnp.where(embed_mask, 0.0, 1.0)
    return Batch(
        tokens=tokens,
        embeds=embeds,
        embed_mask=embed_mask,
        positions=positions,
        targets=targets,
        loss_mask=loss_mask,
    )


# ----------------------------------------------------------------------------
# plain text LM batch (everything else)
# ----------------------------------------------------------------------------


def lm_batch(key, cfg: ModelConfig, batch: int, seq: int) -> Batch:
    tokens = jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)
    positions = jnp.broadcast_to(
        jnp.arange(seq, dtype=jnp.int32)[None], (batch, seq)
    )
    return Batch(
        tokens=tokens,
        embeds=None,
        embed_mask=None,
        positions=positions,
        targets=jnp.roll(tokens, -1, axis=1),
        loss_mask=jnp.ones((batch, seq), jnp.float32),
    )
