"""Mixture-of-Experts layer.

Two implementations sharing one parameter layout:

* ``moe_dense``           — reference: every expert computes every token,
  combined with router weights.  Exact (no capacity dropping).  Used for
  smoke tests / tiny expert counts and as the oracle for the expert-parallel
  path.
* ``moe_expert_parallel`` — production: ``shard_map`` over the mesh, tokens
  sharded on the data axes, experts sharded on the model axis, with
  capacity-based dispatch and two ``all_to_all`` collectives (the classic
  expert-parallel schedule).  When the expert count E is smaller than the
  model-axis size M, each expert is split into ``r = M // E`` *virtual
  experts* that hold a 1/r slice of the FFN hidden dim — tokens are
  dispatched to all r slices and the down-projection partial sums are added
  on the way back (tensor parallelism inside the expert).  This keeps the
  (16,16) production mesh fully used for Mixtral's 8 experts.

Parameter layout (V = E * r virtual experts, F_v = moe_d_ff // r):
  router:  (D, E)
  gate,up: (V, D, F_v)
  down:    (V, F_v, D)
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.shard_compat import shard_map
from repro.models.config import ModelConfig
from repro.models.layers import act_fn, dense_init, is_gated


class MoEShardingCtx(NamedTuple):
    """How the expert-parallel path should map onto the mesh."""

    mesh: object                    # jax.sharding.Mesh
    dp_axes: Tuple[str, ...]        # axes the batch is sharded over
    model_axis: str                 # axis experts are sharded over
    batch_sharded: bool = True      # False for global_batch=1 decode
    # 2D expert parallelism: keep expert weights FSDP-sharded (Fv sliced over
    # the data axes) inside the shard_map; all-gather the *token* buffers
    # over data and reduce-scatter the partial outputs back.  Token buffers
    # are ~7x smaller than Jamba's 19 GB/layer expert weights — this is the
    # memory fix that makes jamba train_4k fit (EXPERIMENTS.md §Perf H3).
    tp_over_dp: bool = False


def virtual_factor(cfg: ModelConfig, model_axis_size: int) -> int:
    """Replica factor r (1 when E >= M)."""
    if cfg.num_experts >= model_axis_size:
        if cfg.num_experts % model_axis_size:
            raise ValueError(
                f"num_experts={cfg.num_experts} not divisible by model axis "
                f"{model_axis_size}"
            )
        return 1
    if model_axis_size % cfg.num_experts:
        raise ValueError(
            f"model axis {model_axis_size} not divisible by "
            f"num_experts={cfg.num_experts}"
        )
    return model_axis_size // cfg.num_experts


def init_moe(key, cfg: ModelConfig, dtype, *, virtual_r: int = 1) -> dict:
    E, D = cfg.num_experts, cfg.d_model
    F = cfg.resolved_moe_d_ff
    assert F % virtual_r == 0, (F, virtual_r)
    V, Fv = E * virtual_r, F // virtual_r
    kr, kg, ku, kd = jax.random.split(key, 4)
    std_in = 1.0 / math.sqrt(D)
    std_out = 1.0 / math.sqrt(F)
    p = {
        "router": dense_init(kr, D, E, dtype=dtype),
        "up": (jax.random.normal(ku, (V, D, Fv)) * std_in).astype(dtype),
        "down": (jax.random.normal(kd, (V, Fv, D)) * std_out).astype(dtype),
    }
    if is_gated(cfg.act):
        p["gate"] = (jax.random.normal(kg, (V, D, Fv)) * std_in).astype(dtype)
    return p


# ----------------------------------------------------------------------------
# router
# ----------------------------------------------------------------------------


def route(params, x, cfg: ModelConfig):
    """x: (T, D) -> (weights (T,k), ids (T,k), aux_loss scalar)."""
    logits = (x.astype(jnp.float32)) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)              # (T, E)
    w, ids = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    w = w / jnp.maximum(w.sum(axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss.
    E = cfg.num_experts
    me = probs.mean(axis=0)                              # mean router prob/exp
    ce = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(
        1.0 / ids.size
    )                                                    # fraction routed/exp
    aux = E * jnp.sum(me * ce) * cfg.router_aux_loss_coef
    return w, ids, aux


def _expert_ffn(params, h, cfg: ModelConfig):
    """h: (V_loc, T, D) grouped tokens; params already V_loc-local."""
    f = act_fn(cfg.act)
    up = jnp.einsum("etd,edf->etf", h, params["up"])
    if "gate" in params:
        g = jnp.einsum("etd,edf->etf", h, params["gate"])
        hidden = f(g) * up
    else:
        hidden = f(up)
    return jnp.einsum("etf,efd->etd", hidden, params["down"])


# ----------------------------------------------------------------------------
# dense reference
# ----------------------------------------------------------------------------


def moe_dense(params, x, cfg: ModelConfig):
    """x: (B,S,D) -> (out, aux).  Computes all experts on all tokens."""
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    w, ids, aux = route(params, xt, cfg)
    V = params["up"].shape[0]
    r = V // cfg.num_experts
    h = jnp.broadcast_to(xt[None], (V, B * S, D))
    y = _expert_ffn(params, h, cfg)                      # (V, T, D)
    # combine: token t takes sum over k of w * sum over r slices
    y = y.reshape(cfg.num_experts, r, B * S, D).sum(axis=1)   # (E, T, D)
    gathered = jnp.take_along_axis(
        jnp.moveaxis(y, 1, 0),                           # (T, E, D)
        ids[..., None],
        axis=1,
    )                                                    # (T, k, D)
    out = (gathered * w[..., None].astype(y.dtype)).sum(axis=1)
    return out.reshape(B, S, D).astype(x.dtype), aux


# ----------------------------------------------------------------------------
# expert parallel (shard_map + all_to_all)
# ----------------------------------------------------------------------------


def _dispatch_positions(ids_flat: jnp.ndarray, E: int, C: int):
    """Per-assignment slot within its expert's capacity buffer.

    ids_flat: (A,) expert id per assignment.  Returns (pos (A,), keep (A,)).
    Sort-based ranking — O(A) memory (a one-hot cumsum would materialize an
    (A, E) intermediate, ~270 MB for the 128-expert 4k-train shape).
    """
    A = ids_flat.shape[0]
    order = jnp.argsort(ids_flat, stable=True)                # (A,)
    sorted_ids = ids_flat[order]
    starts = jnp.searchsorted(sorted_ids, jnp.arange(E))      # (E,)
    pos_sorted = jnp.arange(A) - starts[sorted_ids]
    pos = jnp.zeros((A,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    keep = pos < C
    return pos, keep


def moe_expert_parallel(
    params,
    x,
    cfg: ModelConfig,
    ctx: MoEShardingCtx,
):
    """x: (B,S,D) -> (out, aux) using all_to_all expert parallelism."""
    mesh = ctx.mesh
    M = mesh.shape[ctx.model_axis]
    r = virtual_factor(cfg, M)
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    V = E * r
    per_shard_v = V // M

    # Shard the sequence axis over the model axis too (when divisible): each
    # model shard routes ONLY its token slice.  Without this every model
    # shard routes the full per-data-shard token set — 16x redundant dispatch
    # and expert FLOPs (observed in the first dry-run sweep).  The re-gather
    # of (B,S/M,D) outputs on exit is the standard sequence-parallel MoE
    # boundary cost.
    S = x.shape[1]
    seq_sharded = ctx.batch_sharded and S > 1 and S % M == 0
    if seq_sharded:
        x_spec = P(ctx.dp_axes, ctx.model_axis, None)
    elif ctx.batch_sharded:
        x_spec = P(ctx.dp_axes, None, None)
    else:
        x_spec = P(None, None, None)
    # params: router replicated; expert weights sharded on V axis.
    # tp_over_dp: the hidden (Fv) dim additionally stays sliced over the
    # data axes inside the shard_map (no per-layer weight gather).
    tp = ctx.tp_over_dp and ctx.batch_sharded
    fv = ctx.dp_axes if tp else None
    pspec = {
        "router": P(None, None),
        "up": P(ctx.model_axis, None, fv),
        "down": P(ctx.model_axis, fv, None),
    }
    if "gate" in params:
        pspec["gate"] = P(ctx.model_axis, None, fv)

    def body(p, xl):
        B_loc, S_loc, D = xl.shape
        T = B_loc * S_loc
        xt = xl.reshape(T, D)
        w, ids, aux = route(p, xt, cfg)                   # (T,k),(T,k)
        A = T * k
        ids_f = ids.reshape(A)
        w_f = w.reshape(A)
        # capacity per (source shard, real expert)
        C = max(1, int(math.ceil(A / E * cfg.moe_capacity_factor)))
        pos, keep = _dispatch_positions(ids_f, E, C)
        # send buffer (V, C, D): replica j of expert e is virtual expert e*r+j
        src = jnp.repeat(xt, k, axis=0)                   # (A, D)
        buf = jnp.zeros((V, C, D), xl.dtype)
        for j in range(r):
            ve = ids_f * r + j
            buf = buf.at[
                jnp.where(keep, ve, 0),
                jnp.where(keep, pos, 0),
            ].add(jnp.where(keep[:, None], src, 0))
        # all_to_all over model axis: (V,C,D)->(M, pv, C, D) split/concat
        buf = buf.reshape(M, per_shard_v, C, D)
        recv = jax.lax.all_to_all(
            buf, ctx.model_axis, split_axis=0, concat_axis=0, tiled=False
        )                                                 # (M, pv, C, D)
        recv = recv.transpose(1, 0, 2, 3).reshape(per_shard_v, M * C, D)
        if tp:
            # 2D EP: gather every data shard's expert tokens, compute with
            # the local Fv slice, reduce-scatter partial outputs back.
            ndp = 1
            for a in ctx.dp_axes:
                ndp *= mesh.shape[a]
            recv_all = jax.lax.all_gather(
                recv, ctx.dp_axes, axis=1, tiled=True
            )                                             # (pv, ndp*M*C, D)
            out_all = _expert_ffn(p, recv_all, cfg)       # partial over Fv
            out_e = jax.lax.psum_scatter(
                out_all, ctx.dp_axes, scatter_dimension=1, tiled=True
            )                                             # (pv, M*C, D)
        else:
            out_e = _expert_ffn(p, recv, cfg)             # (pv, M*C, D)
        out_e = out_e.reshape(per_shard_v, M, C, D).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(
            out_e, ctx.model_axis, split_axis=0, concat_axis=0, tiled=False
        )                                                 # (M, pv, C, D)
        back = back.reshape(V, C, D)
        # gather + combine replicas and top-k
        y = jnp.zeros((A, D), jnp.float32)
        for j in range(r):
            ve = ids_f * r + j
            y = y + jnp.where(
                keep[:, None], back[ve, pos].astype(jnp.float32), 0.0
            )
        y = (y * w_f[:, None]).reshape(T, k, D).sum(axis=1)
        # aux loss averaged over data shards happens outside (scalar psum-mean
        # via replicated output would need collective; return local aux).
        if ctx.batch_sharded:
            axes = ctx.dp_axes + ((ctx.model_axis,) if seq_sharded else ())
            aux = jax.lax.pmean(aux, axes)
        return y.reshape(B_loc, S_loc, D).astype(xl.dtype), aux

    out, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(pspec, x_spec),
        out_specs=(x_spec, P()),
    )(params, x)
    return out, aux


def apply_moe(params, x, cfg: ModelConfig, ctx: Optional[MoEShardingCtx]):
    impl = cfg.moe_impl
    if impl == "auto":
        impl = "expert_parallel" if ctx is not None else "dense"
    if impl == "expert_parallel":
        assert ctx is not None, "expert_parallel MoE requires a sharding ctx"
        return moe_expert_parallel(params, x, cfg, ctx)
    return moe_dense(params, x, cfg)
