"""Decode-cache containers for the heterogeneous layer stack.

A model cache is ``{"units": stacked_pytree, "tail": (per-layer, ...)}`` where
the stacked pytree has a leading ``num_units`` axis so the decode step can
``lax.scan`` over (unit_params, unit_cache) together.

Per-layer cache by mixer kind:
  attn / attn_global : {"k": (B, max_len, Kv, hd), "v": ..., "pos": (B, max_len)}
  attn_swa / local   : same, but length min(window, max_len) (ring buffer)
  mamba              : {"conv": (B, dc-1, din), "ssm": (B, din, ds)}
  rwkv6              : {"tm": {shift, wkv}, "cm": {shift}}
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import mamba as mamba_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.attention import is_windowed
from repro.models.config import ModelConfig, LayerSpec


def attn_cache_len(cfg: ModelConfig, mixer: str, max_len: int) -> int:
    if is_windowed(mixer) and cfg.sliding_window > 0:
        return min(cfg.sliding_window, max_len)
    return max_len


def init_layer_cache(
    cfg: ModelConfig, spec: LayerSpec, batch: int, max_len: int, dtype
):
    if spec.mixer.startswith("attn"):
        L = attn_cache_len(cfg, spec.mixer, max_len)
        hd = cfg.resolved_head_dim
        return {
            "k": jnp.zeros((batch, L, cfg.num_kv_heads, hd), dtype),
            "v": jnp.zeros((batch, L, cfg.num_kv_heads, hd), dtype),
            "pos": jnp.full((batch, L), -1, jnp.int32),
        }
    if spec.mixer == "mamba":
        return mamba_mod.init_mamba_state(cfg, batch, dtype)
    if spec.mixer == "rwkv6":
        return rwkv_mod.init_rwkv_state(cfg, batch, dtype)
    raise ValueError(spec.mixer)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    unit = tuple(
        init_layer_cache(cfg, spec, batch, max_len, dtype) for spec in cfg.unit
    )
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.num_units,) + x.shape).copy()
        if cfg.num_units
        else x,
        unit,
    )
    tail = tuple(
        init_layer_cache(cfg, spec, batch, max_len, dtype) for spec in cfg.tail
    )
    return {"units": stacked, "tail": tail}


def insert_slot_cache(cache: dict, slot_cache: dict, b) -> dict:
    """Write a batch-1 cache (one request, e.g. fresh from prefill) into batch
    row ``b`` of a batched decode cache.

    This is the continuous-batching admission primitive: a finished slot's
    rows are overwritten in place by the next request's prefilled KV state,
    with no barrier on the other slots.  ``b`` may be a traced int32 scalar,
    so one jitted insert serves every slot.  Unit leaves carry the stacked
    ``(num_units, B, ...)`` layout (batch axis 1); tail leaves are plain
    ``(B, ...)`` (batch axis 0).
    """

    def ins(axis):
        def f(big, small):
            return jax.lax.dynamic_update_slice_in_dim(
                big, small.astype(big.dtype), b, axis
            )
        return f

    return {
        "units": jax.tree.map(ins(1), cache["units"], slot_cache["units"]),
        "tail": jax.tree.map(ins(0), cache["tail"], slot_cache["tail"]),
    }
