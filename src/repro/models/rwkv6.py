"""RWKV-6 "Finch" time-mix (data-dependent decay) and channel-mix.

TPU adaptation (DESIGN.md §4): instead of the token-sequential CUDA WKV
kernel, training/prefill use a *chunked* linear-attention form — within a
chunk of L tokens the recurrence is expressed as masked (L, L) matmuls with
log-space cumulative decay (MXU-friendly); across chunks a ``lax.scan``
carries the (H, dh, dh) state.  Decode is the exact single-step recurrence.

Recurrence (per head, dh-dim r/k/v, state S in R^{dh x dh}):
    y_t = r_t @ (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
with per-channel data-dependent decay w_t in (0,1).

Log-decay differences are clamped to [-LOG_CLAMP, 0] before exponentiation —
a contribution decayed by e^-30 is numerically zero, so the clamp changes
nothing while preventing overflow of the 1/prod(w) ratio trick.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init

CHUNK = 64
LOG_CLAMP = 30.0

N_SHIFT = 5  # r, k, v, g, w token-shift interpolants


def init_rwkv_time_mix(key, cfg: ModelConfig, dtype) -> dict:
    D = cfg.d_model
    dh = cfg.rwkv_head_dim
    H = D // dh
    L1 = cfg.rwkv_lora_mix
    L2 = cfg.rwkv_lora_decay
    ks = jax.random.split(key, 12)
    return {
        # token-shift interpolation: base mus + DDLoRA producing 5 deltas
        "mu_base": jnp.full((D,), 0.5, dtype),
        "mu": jnp.full((N_SHIFT, D), 0.5, dtype),
        "mix_w1": dense_init(ks[0], D, N_SHIFT * L1, dtype=dtype),
        "mix_w2": (
            jax.random.normal(ks[1], (N_SHIFT, L1, D)) / math.sqrt(L1)
        ).astype(dtype),
        # projections
        "wr": dense_init(ks[2], D, D, dtype=dtype),
        "wk": dense_init(ks[3], D, D, dtype=dtype),
        "wv": dense_init(ks[4], D, D, dtype=dtype),
        "wg": dense_init(ks[5], D, D, dtype=dtype),
        "wo": dense_init(ks[6], D, D, dtype=dtype),
        # data-dependent decay DDLoRA
        "w0": jnp.full((D,), -4.0, dtype),
        "decay_w1": dense_init(ks[7], D, L2, dtype=dtype),
        "decay_w2": (jax.random.normal(ks[8], (L2, D)) / math.sqrt(L2)).astype(
            dtype
        ),
        # per-channel bonus
        "u": (jax.random.normal(ks[9], (D,)) * 0.1).astype(dtype),
        # post-WKV group norm (one group per head)
        "gn_scale": jnp.ones((D,), dtype),
        "gn_bias": jnp.zeros((D,), dtype),
    }


def init_rwkv_channel_mix(key, cfg: ModelConfig, dtype) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((D,), 0.5, dtype),
        "mu_r": jnp.full((D,), 0.5, dtype),
        "wk": dense_init(k1, D, F, dtype=dtype),
        "wv": dense_init(k2, F, D, dtype=dtype),
        "wr": dense_init(k3, D, D, dtype=dtype),
    }


# ----------------------------------------------------------------------------
# shared pieces
# ----------------------------------------------------------------------------


def _token_shift_vectors(params, x, x_prev):
    """Compute the 5 interpolated inputs (r,k,v,g,w) for time mix.

    x: (B,S,D); x_prev: (B,D) last token of the previous segment (zeros at
    sequence start).  Returns (B,S,5,D)."""
    B, S, D = x.shape
    shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    sx = shifted - x                                       # (B,S,D)
    xxx = x + sx * params["mu_base"]
    hid = jnp.tanh(xxx @ params["mix_w1"]).reshape(B, S, N_SHIFT, -1)
    delta = jnp.einsum("bsnl,nld->bsnd", hid, params["mix_w2"])
    mix = params["mu"][None, None] + delta                 # (B,S,5,D)
    return x[:, :, None, :] + sx[:, :, None, :] * mix


def _decay_log(params, xw):
    """log(w_t) in (-inf, 0): w = exp(-exp(w0 + lora(xw)))."""
    lora = jnp.tanh(xw @ params["decay_w1"]) @ params["decay_w2"]
    return -jnp.exp(
        jnp.clip(params["w0"].astype(jnp.float32) + lora.astype(jnp.float32), -8.0, 8.0)
    )


def _group_norm(params, y, H):
    """Per-head LayerNorm (GroupNorm with H groups)."""
    B, S, D = y.shape
    yh = y.reshape(B, S, H, D // H).astype(jnp.float32)
    mean = yh.mean(axis=-1, keepdims=True)
    var = yh.var(axis=-1, keepdims=True)
    yh = (yh - mean) * jax.lax.rsqrt(var + 1e-5)
    y = yh.reshape(B, S, D)
    return (y * params["gn_scale"].astype(jnp.float32)
            + params["gn_bias"].astype(jnp.float32))


# ----------------------------------------------------------------------------
# chunked WKV (training / prefill)
# ----------------------------------------------------------------------------


def _wkv_chunked(r, k, v, logw, u, state0):
    """r,k,v: (B,S,H,dh); logw: (B,S,H,dh) (<=0); u: (H,dh);
    state0: (B,H,dh,dh).  S % CHUNK == 0.  Returns (y (B,S,H,dh), state)."""
    B, S, H, dh = r.shape
    n = S // CHUNK

    def chunk_body(state, inp):
        rc, kc, vc, lwc = inp            # (B,L,H,dh) each
        # inclusive cumulative log decay within the chunk
        a_inc = jnp.cumsum(lwc, axis=1)                   # (B,L,H,dh)
        a_exc = a_inc - lwc                               # sum_{s<t}
        # state contribution: y_t += (r_t * exp(a_exc_t)) @ S
        r_dec = rc * jnp.exp(jnp.maximum(a_exc, -LOG_CLAMP))
        y_state = jnp.einsum("blhd,bhde->blhe", r_dec, state)
        # intra-chunk scores: s_tj = sum_d r_td k_jd exp(a_exc_t - a_inc_j)
        k_dec = kc * jnp.exp(jnp.maximum(-a_inc, -LOG_CLAMP))
        scores = jnp.einsum("blhd,bmhd->bhlm", r_dec, k_dec)
        tri = jnp.tril(jnp.ones((CHUNK, CHUNK), bool), k=-1)
        scores = jnp.where(tri[None, None], scores, 0.0)
        # diagonal bonus term: j == t
        diag = jnp.einsum("blhd,blhd->blh", rc, u[None, None] * kc)
        y_intra = jnp.einsum("bhlm,bmhe->blhe", scores, vc)
        y_intra = y_intra + diag[..., None] * vc
        # new state: S' = diag(exp(a_L)) S + sum_j (k_j exp(a_L - a_inc_j)) v_j^T
        a_tot = a_inc[:, -1]                              # (B,H,dh)
        k_tail = kc * jnp.exp(
            jnp.maximum(a_tot[:, None] - a_inc, -LOG_CLAMP)
        )
        state_new = state * jnp.exp(jnp.maximum(a_tot, -LOG_CLAMP))[..., None]
        state_new = state_new + jnp.einsum("blhd,blhe->bhde", k_tail, vc)
        return state_new, y_state + y_intra

    rs = r.reshape(B, n, CHUNK, H, dh).swapaxes(0, 1)
    ks_ = k.reshape(B, n, CHUNK, H, dh).swapaxes(0, 1)
    vs = v.reshape(B, n, CHUNK, H, dh).swapaxes(0, 1)
    lws = logw.reshape(B, n, CHUNK, H, dh).swapaxes(0, 1)
    state, ys = jax.lax.scan(chunk_body, state0, (rs, ks_, vs, lws))
    y = ys.swapaxes(0, 1).reshape(B, S, H, dh)
    return y, state


def _wkv_step(r, k, v, logw, u, state):
    """Exact single-token recurrence.  r,k,v,logw: (B,H,dh); state (B,H,dh,dh)."""
    y = jnp.einsum("bhd,bhde->bhe", r, state)
    kv = jnp.einsum("bhd,bhe->bhde", k, v)
    y = y + jnp.einsum("bhd,bhde->bhe", r * u[None], kv)
    state = state * jnp.exp(logw)[..., None] + kv
    return y, state


# ----------------------------------------------------------------------------
# public API
# ----------------------------------------------------------------------------


def rwkv_time_mix_forward(params, x, cfg: ModelConfig, state=None):
    """Full-sequence time mix.  x: (B,S,D).

    state: None (fresh) or dict(shift (B,D), wkv (B,H,dh,dh)).
    Returns (out, new_state)."""
    B, S, D = x.shape
    dh = cfg.rwkv_head_dim
    H = D // dh
    x_prev = state["shift"] if state else jnp.zeros((B, D), x.dtype)
    wkv0 = (
        state["wkv"]
        if state
        else jnp.zeros((B, H, dh, dh), jnp.float32)
    )
    xi = _token_shift_vectors(params, x, x_prev)          # (B,S,5,D)
    xr, xk, xv, xg, xw = (xi[:, :, i] for i in range(N_SHIFT))
    r = (xr @ params["wr"]).reshape(B, S, H, dh).astype(jnp.float32)
    k = (xk @ params["wk"]).reshape(B, S, H, dh).astype(jnp.float32)
    v = (xv @ params["wv"]).reshape(B, S, H, dh).astype(jnp.float32)
    g = jax.nn.silu(xg @ params["wg"])
    logw = _decay_log(params, xw).reshape(B, S, H, dh)
    u = params["u"].astype(jnp.float32).reshape(H, dh)

    pad = (-S) % CHUNK
    if pad:
        padf = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, wkv = _wkv_chunked(padf(r), padf(k), padf(v), padf(logw), u, wkv0)
        y = y[:, :S]
        # padded steps have k=v=0 and logw=0 -> state unchanged by padding
    else:
        y, wkv = _wkv_chunked(r, k, v, logw, u, wkv0)

    y = _group_norm(params, y.reshape(B, S, D), H)
    out = (y.astype(x.dtype) * g) @ params["wo"]
    new_state = {"shift": x[:, -1, :], "wkv": wkv}
    return out, new_state


def rwkv_time_mix_step(params, x, cfg: ModelConfig, state):
    """Single-token decode.  x: (B,1,D)."""
    B, _, D = x.shape
    dh = cfg.rwkv_head_dim
    H = D // dh
    xi = _token_shift_vectors(params, x, state["shift"])   # (B,1,5,D)
    xr, xk, xv, xg, xw = (xi[:, 0, i] for i in range(N_SHIFT))
    r = (xr @ params["wr"]).reshape(B, H, dh).astype(jnp.float32)
    k = (xk @ params["wk"]).reshape(B, H, dh).astype(jnp.float32)
    v = (xv @ params["wv"]).reshape(B, H, dh).astype(jnp.float32)
    g = jax.nn.silu(xg @ params["wg"])
    logw = _decay_log(params, xw).reshape(B, H, dh)
    u = params["u"].astype(jnp.float32).reshape(H, dh)
    y, wkv = _wkv_step(r, k, v, logw, u, state["wkv"])
    y = _group_norm(params, y.reshape(B, 1, D), H)
    out = (y.astype(x.dtype) * g[:, None, :].reshape(B, 1, D)) @ params["wo"]
    return out, {"shift": x[:, -1, :], "wkv": wkv}


def rwkv_channel_mix_forward(params, x, cfg: ModelConfig, state=None):
    """x: (B,S,D) -> (out, new_state(shift))."""
    B, S, D = x.shape
    x_prev = state["shift"] if state else jnp.zeros((B, D), x.dtype)
    shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    sx = shifted - x
    xk = x + sx * params["mu_k"]
    xr = x + sx * params["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ params["wk"]))
    v = k @ params["wv"]
    out = jax.nn.sigmoid(xr @ params["wr"]) * v
    return out, {"shift": x[:, -1, :]}


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    D = cfg.d_model
    dh = cfg.rwkv_head_dim
    H = D // dh
    return {
        "tm": {
            "shift": jnp.zeros((batch, D), dtype),
            "wkv": jnp.zeros((batch, H, dh, dh), jnp.float32),
        },
        "cm": {"shift": jnp.zeros((batch, D), dtype)},
    }
