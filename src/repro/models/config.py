"""Model configuration schema for the repro model zoo.

A model is a stack of layer *units*: a unit is a short heterogeneous sequence
of layers (e.g. Jamba's ``7 x mamba + 1 x attn`` period, Gemma-3's
``5 x local + 1 x global`` period) that repeats ``num_units`` times, plus an
optional non-repeating ``tail``.  Homogeneous models (most) have a unit of a
single layer.  The repeating structure lets the forward pass ``lax.scan`` over
stacked unit parameters, which keeps HLO size and compile time independent of
depth — essential for the 33-combination multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# Mixer kinds -----------------------------------------------------------------
ATTN = "attn"                # full (causal or bidirectional) attention
ATTN_SWA = "attn_swa"        # sliding-window attention (window from config)
ATTN_LOCAL = "attn_local"    # alias of SWA used by local:global patterns
ATTN_GLOBAL = "attn_global"  # full attention inside a local:global pattern
MAMBA = "mamba"              # selective SSM (Mamba-1, as in Jamba)
RWKV = "rwkv6"               # RWKV-6 "Finch" data-dependent-decay time mix

# MLP kinds -------------------------------------------------------------------
MLP_DENSE = "dense"
MLP_MOE = "moe"
MLP_RWKV = "rwkv_channel_mix"  # RWKV channel mix replaces the MLP
MLP_NONE = "none"


@dataclass(frozen=True)
class LayerSpec:
    """One layer inside a repeating unit."""

    mixer: str = ATTN
    mlp: str = MLP_DENSE


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str              # dense | moe | ssm | hybrid | audio | vlm
    d_model: int
    vocab_size: int
    # Layer stack: unit repeated num_units times, then tail.
    unit: Tuple[LayerSpec, ...]
    num_units: int
    tail: Tuple[LayerSpec, ...] = ()

    # ---- attention ----
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0           # 0 -> d_model // num_heads
    attention_bias: bool = False       # QKV bias (Qwen1.5)
    causal: bool = True                # False for encoder-only (HuBERT)
    sliding_window: int = 0            # window for SWA / local layers
    rope: str = "standard"             # "standard" | "mrope" | "none"
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()   # head_dim/2 split for M-RoPE (t,h,w)
    attn_logit_softcap: float = 0.0

    # ---- MLP ----
    d_ff: int = 0
    act: str = "swiglu"                # swiglu | gelu | geglu
    mlp_bias: bool = False

    # ---- norm / embeddings ----
    norm: str = "rmsnorm"              # rmsnorm | layernorm | layernorm_np
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    scale_embeddings: bool = False     # Gemma-style sqrt(d) embed scaling

    # ---- MoE ----
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0                  # per-expert FF dim (0 -> d_ff)
    moe_capacity_factor: float = 1.25
    router_aux_loss_coef: float = 0.01

    # ---- RWKV-6 ----
    rwkv_head_dim: int = 64
    rwkv_lora_mix: int = 32            # token-shift DDLoRA rank
    rwkv_lora_decay: int = 64          # decay DDLoRA rank

    # ---- Mamba (Jamba-style) ----
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0             # 0 -> ceil(d_model / 16)

    # ---- modality frontend stub ----
    frontend: str = ""                 # "" | "audio" | "vision"

    # ---- runtime ----
    dtype: str = "float32"             # activation/param dtype name
    remat: object = False              # False | True (unit) | "layer"
    moe_impl: str = "auto"             # auto | dense | expert_parallel
    citation: str = ""

    # ------------------------------------------------------------------
    @property
    def num_layers(self) -> int:
        return len(self.unit) * self.num_units + len(self.tail)

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def resolved_moe_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def resolved_dt_rank(self) -> int:
        if self.mamba_dt_rank:
            return self.mamba_dt_rank
        return -(-self.d_model // 16)

    def all_layers(self) -> Tuple[LayerSpec, ...]:
        return self.unit * self.num_units + self.tail

    def has_mixer(self, kind: str) -> bool:
        return any(l.mixer == kind for l in self.all_layers())

    def has_attention(self) -> bool:
        return any(l.mixer.startswith("attn") for l in self.all_layers())

    def is_subquadratic(self) -> bool:
        """True when no layer keeps an unbounded full-attention KV cache.

        SSM / RWKV state is O(1); sliding-window layers keep a bounded window.
        Models that are hybrids with a *few* full-attention layers (Jamba,
        Gemma-3 local:global) are treated as effectively sub-quadratic for the
        long-context shape per DESIGN.md §5.
        """
        layers = self.all_layers()
        full = sum(1 for l in layers if l.mixer in (ATTN, ATTN_GLOBAL))
        if full == 0:
            return True
        # hybrid carve-out: bounded fraction of full-attention layers
        return full / len(layers) <= 0.25

    def is_decoder(self) -> bool:
        return self.causal

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def dense_unit(n: int = 1, mixer: str = ATTN) -> Tuple[LayerSpec, ...]:
    return tuple(LayerSpec(mixer=mixer, mlp=MLP_DENSE) for _ in range(n))


def moe_unit(n: int = 1, mixer: str = ATTN) -> Tuple[LayerSpec, ...]:
    return tuple(LayerSpec(mixer=mixer, mlp=MLP_MOE) for _ in range(n))
