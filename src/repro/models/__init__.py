from repro.models.config import LayerSpec, ModelConfig
from repro.models.transformer import (
    Batch,
    decode_step,
    forward,
    init_model,
    prefill,
)
from repro.models.cache import init_cache

__all__ = [
    "Batch",
    "LayerSpec",
    "ModelConfig",
    "decode_step",
    "forward",
    "init_cache",
    "init_model",
    "prefill",
]
