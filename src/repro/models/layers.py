"""Shared neural-net building blocks (pure JAX, no framework deps).

Parameters are plain nested dicts of jnp arrays.  Every ``init_*`` takes a
PRNG key and returns a pytree; every ``apply`` is a pure function.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

# ----------------------------------------------------------------------------
# init helpers
# ----------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_dim: int, *, dtype, scale: float = 1.0):
    std = scale / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim)) * std).astype(dtype)


def embed_init(key, vocab: int, dim: int, *, dtype):
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


# ----------------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------------


def init_norm(cfg: ModelConfig, dtype) -> dict:
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        return {
            "scale": jnp.ones((cfg.d_model,), dtype),
            "bias": jnp.zeros((cfg.d_model,), dtype),
        }
    if cfg.norm == "layernorm_np":  # OLMo non-parametric LN
        return {}
    raise ValueError(f"unknown norm {cfg.norm!r}")


def apply_norm(params: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    eps = cfg.norm_eps
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)
    # layernorm variants
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    if cfg.norm == "layernorm":
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(
            jnp.float32
        )
    return y.astype(x.dtype)


# ----------------------------------------------------------------------------
# activations
# ----------------------------------------------------------------------------


def act_fn(name: str):
    if name in ("swiglu", "silu"):
        return jax.nn.silu
    if name in ("gelu", "geglu"):
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(f"unknown activation {name!r}")


def is_gated(name: str) -> bool:
    return name in ("swiglu", "geglu")


# ----------------------------------------------------------------------------
# dense MLP
# ----------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, dtype, d_ff: int = 0) -> dict:
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"down": dense_init(k2, d_ff, cfg.d_model, dtype=dtype)}
    if is_gated(cfg.act):
        p["gate"] = dense_init(k1, cfg.d_model, d_ff, dtype=dtype)
        p["up"] = dense_init(k3, cfg.d_model, d_ff, dtype=dtype)
    else:
        p["up"] = dense_init(k1, cfg.d_model, d_ff, dtype=dtype)
    if cfg.mlp_bias:
        p["up_b"] = jnp.zeros((d_ff,), dtype)
        p["down_b"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def apply_mlp(params: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    f = act_fn(cfg.act)
    if is_gated(cfg.act):
        h = f(x @ params["gate"]) * (x @ params["up"])
    else:
        h = x @ params["up"]
        if "up_b" in params:
            h = h + params["up_b"]
        h = f(h)
    y = h @ params["down"]
    if "down_b" in params:
        y = y + params["down_b"]
    return y


# ----------------------------------------------------------------------------
# rotary embeddings (standard + M-RoPE)
# ----------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(
    x: jnp.ndarray,          # (B, S, H, Dh)
    positions: jnp.ndarray,  # (B, S) int32
    theta: float,
) -> jnp.ndarray:
    freqs = rope_frequencies(x.shape[-1], theta)           # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,Dh/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray,          # (B, S, H, Dh)
    positions: jnp.ndarray,  # (3, B, S) int32 — (t, h, w) streams
    theta: float,
    sections: Tuple[int, ...],
) -> jnp.ndarray:
    """Multimodal RoPE (Qwen2-VL): head_dim/2 frequency slots are partitioned
    into (temporal, height, width) sections, each rotated by its own position
    stream.  For pure-text tokens all three streams coincide and M-RoPE
    reduces exactly to standard RoPE."""
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_frequencies(x.shape[-1], theta)            # (half,)
    # Build the per-slot position by selecting the stream for each section.
    stream_id = jnp.concatenate(
        [jnp.full((n,), i, dtype=jnp.int32) for i, n in enumerate(sections)]
    )                                                        # (half,)
    # positions: (3,B,S) -> (B,S,half) selecting stream per slot
    pos = jnp.take_along_axis(
        jnp.moveaxis(positions, 0, -1),                      # (B,S,3)
        stream_id[None, None, :],                            # (1,1,half)
        axis=-1,
    )                                                        # (B,S,half)
    angles = pos.astype(jnp.float32) * freqs                 # (B,S,half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# conv positional embedding (HuBERT-style, grouped 1-D conv over time)
# ----------------------------------------------------------------------------


def init_conv_pos(key, cfg: ModelConfig, dtype, kernel: int = 31, groups: int = 16):
    per_group = cfg.d_model // groups
    w = jax.random.normal(key, (kernel, per_group, cfg.d_model)) * (
        1.0 / math.sqrt(kernel * per_group)
    )
    return {"w": w.astype(dtype), "b": jnp.zeros((cfg.d_model,), dtype)}


def apply_conv_pos(params: dict, x: jnp.ndarray, groups: int = 16) -> jnp.ndarray:
    # x: (B, S, D); grouped conv over S with 'SAME' padding.
    y = jax.lax.conv_general_dilated(
        x,
        params["w"],
        window_strides=(1,),
        padding="SAME",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=groups,
    )
    return jax.nn.gelu(y + params["b"], approximate=True)
