"""Pure-JAX flash attention with a custom VJP (recompute-based backward).

Structure (v3 — the v1->v2->v3 story is EXPERIMENTS.md §Perf P0/P2/H-pre):

* Q blocks are a *batched* dim, not an outer scan (v2): GSPMD can shard them.
* ALL blocked tensors share one canonical layout (B, nq, Kv, G, QB, ...) and
  one ``block_spec`` constraint — q, the (m, l, acc) carries, lse, and the
  backward's dout/delta.  v2 constrained only q: the *carries* were free, so
  the saved lse could land with a different sharding than the H-sharded
  score blocks and the backward all-gathered every P tile (1.6 TB/device on
  qwen3 train_4k).
* One ``lax.scan`` over KV blocks; custom_vjp saves (q, k, v, out, lse) and
  recomputes P per block — O(S) memory, canonical ~2x attention recompute.
* KV blocks stay bf16; all einsums accumulate f32.

GQA layout: q (B,Sq,H,Dh); k,v (B,Skv,Kv,Dh); H = Kv*G (callers that want
clean 16-way head sharding pass kv expanded to H, i.e. G=1).
``block_spec`` is a 6-entry PartitionSpec over (B, nq, Kv, G, QB, Dh/KB),
trimmed to each tensor's rank: entry 1 shards q-blocks, entry 2 shards heads.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

KV_BLOCK = 512
NEG_INF = -1e30


def pick_q_block(seq: int, model_size: int, max_block: int = 512) -> int:
    """Largest block <= max_block such that (seq/block) % model_size == 0
    (falls back to max_block when impossible)."""
    for qb in (512, 256, 128, 64):
        if qb > max_block:
            continue
        nq = seq // qb
        if seq % qb == 0 and nq % model_size == 0:
            return qb
    return max_block


def _pair_mask(q_pos, kv_pos, *, causal: bool, window: int):
    q = q_pos[..., :, None]
    k = kv_pos[..., None, :]
    ok = k >= 0
    if causal:
        ok = ok & (k <= q)
    if window > 0:
        ok = ok & (q - k < window)
    return ok


def _constrain(x, spec, mesh):
    if spec is None or mesh is None:
        return x
    from jax.sharding import PartitionSpec as P

    trimmed = P(*tuple(spec)[: x.ndim])  # canonical (B,nq,Kv,G,...) prefix
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, trimmed)
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9))
def flash_attention(
    q, k, v, q_pos, kv_pos,
    causal: bool, window: int, q_block: int = 512,
    block_spec=None, mesh=None,
):
    """Returns out (B,Sq,H,Dh).  Sq % q_block == 0, Skv % KV_BLOCK == 0."""
    out, _ = _fwd_impl(q, k, v, q_pos, kv_pos, causal, window, q_block,
                       block_spec, mesh)
    return out


def _block_q(t, nq, q_block, Kv, G, Dh):
    """(B,Sq,H,Dh) -> canonical (B,nq,Kv,G,QB,Dh)."""
    B = t.shape[0]
    return t.reshape(B, nq, q_block, Kv, G, Dh).transpose(0, 1, 3, 4, 2, 5)


def _unblock_q(t, B, Sq, H, Dh):
    """(B,nq,Kv,G,QB,Dh) -> (B,Sq,H,Dh)."""
    return t.transpose(0, 1, 4, 2, 3, 5).reshape(B, Sq, H, Dh)


def _fwd_impl(q, k, v, q_pos, kv_pos, causal, window, q_block,
              block_spec, mesh):
    B, Sq, H, Dh = q.shape
    Skv, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    nq = Sq // q_block
    nk = Skv // KV_BLOCK

    qf = _constrain(
        _block_q(q.astype(jnp.float32) * (Dh ** -0.5), nq, q_block, Kv, G, Dh),
        block_spec, mesh,
    )                                                   # (B,nq,Kv,G,QB,Dh)
    qp = q_pos.reshape(B, nq, q_block)
    kf = jnp.moveaxis(k.reshape(B, nk, KV_BLOCK, Kv, Dh), 1, 0)  # bf16 ok
    vf = jnp.moveaxis(v.reshape(B, nk, KV_BLOCK, Kv, Dh), 1, 0)
    kp = jnp.moveaxis(kv_pos.reshape(B, nk, KV_BLOCK), 1, 0)

    def kv_body(carry, ki):
        m, l, acc = carry                               # (B,nq,Kv,G,QB[,Dh])
        kb, vb, kpb = ki
        s = jnp.einsum("bnkgqd,bskd->bnkgqs", qf, kb,
                       preferred_element_type=jnp.float32)
        mask = _pair_mask(qp, kpb[:, None], causal=causal, window=window)
        s = jnp.where(mask[:, :, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale = jnp.exp(m - m_new)
        l_new = l * scale + p.sum(axis=-1)
        acc_new = acc * scale[..., None] + jnp.einsum(
            "bnkgqs,bskd->bnkgqd", p, vb,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    init = (
        _constrain(jnp.full((B, nq, Kv, G, q_block), NEG_INF, jnp.float32),
                   block_spec, mesh),
        _constrain(jnp.zeros((B, nq, Kv, G, q_block), jnp.float32),
                   block_spec, mesh),
        _constrain(jnp.zeros((B, nq, Kv, G, q_block, Dh), jnp.float32),
                   block_spec, mesh),
    )
    (m, l, acc), _ = jax.lax.scan(kv_body, init, (kf, vf, kp))
    l_safe = jnp.maximum(l, 1e-30)
    out = acc / l_safe[..., None]                       # (B,nq,Kv,G,QB,Dh)
    lse = _constrain(m + jnp.log(l_safe), block_spec, mesh)
    out = _unblock_q(out, B, Sq, H, Dh).astype(q.dtype)
    return out, lse


def _fwd(q, k, v, q_pos, kv_pos, causal, window, q_block, block_spec, mesh):
    out, lse = _fwd_impl(q, k, v, q_pos, kv_pos, causal, window, q_block,
                         block_spec, mesh)
    return out, (q, k, v, q_pos, kv_pos, out, lse)


def _bwd(causal, window, q_block, block_spec, mesh, res, dout):
    q, k, v, q_pos, kv_pos, out, lse = res
    B, Sq, H, Dh = q.shape
    Skv, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    nq = Sq // q_block
    nk = Skv // KV_BLOCK
    scale = Dh ** -0.5

    qf = _constrain(
        _block_q(q.astype(jnp.float32) * scale, nq, q_block, Kv, G, Dh),
        block_spec, mesh,
    )
    dof = _constrain(
        _block_q(dout.astype(jnp.float32), nq, q_block, Kv, G, Dh),
        block_spec, mesh,
    )
    of = _block_q(out.astype(jnp.float32), nq, q_block, Kv, G, Dh)
    qp = q_pos.reshape(B, nq, q_block)
    kf = jnp.moveaxis(k.reshape(B, nk, KV_BLOCK, Kv, Dh), 1, 0)
    vf = jnp.moveaxis(v.reshape(B, nk, KV_BLOCK, Kv, Dh), 1, 0)
    kp = jnp.moveaxis(kv_pos.reshape(B, nk, KV_BLOCK), 1, 0)
    lse = _constrain(lse, block_spec, mesh)             # (B,nq,Kv,G,QB)
    delta = _constrain(
        jnp.einsum("bnkgqd,bnkgqd->bnkgq", dof, of), block_spec, mesh
    )

    def kv_body(dq, ki):
        kb, vb, kpb = ki
        s = jnp.einsum("bnkgqd,bskd->bnkgqs", qf, kb,
                       preferred_element_type=jnp.float32)
        mask = _pair_mask(qp, kpb[:, None], causal=causal, window=window)
        s = jnp.where(mask[:, :, None, None], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                 # (B,nq,Kv,G,QB,KB)
        dp = jnp.einsum("bnkgqd,bskd->bnkgqs", dof, vb,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None])
        dq = dq + jnp.einsum("bnkgqs,bskd->bnkgqd", ds, kb,
                             preferred_element_type=jnp.float32)
        dk_b = jnp.einsum("bnkgqs,bnkgqd->bskd", ds, qf)
        dv_b = jnp.einsum("bnkgqs,bnkgqd->bskd", p, dof)
        return dq, (dk_b, dv_b)

    dq0 = jnp.zeros_like(qf)
    dq, (dks, dvs) = jax.lax.scan(kv_body, dq0, (kf, vf, kp))
    dq = (_unblock_q(dq, B, Sq, H, Dh) * scale).astype(q.dtype)
    dk = jnp.moveaxis(dks, 0, 1).reshape(B, Skv, Kv, Dh).astype(k.dtype)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(B, Skv, Kv, Dh).astype(v.dtype)
    return dq, dk, dv, None, None


flash_attention.defvjp(_fwd, _bwd)
