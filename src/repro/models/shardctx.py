"""ShardCtx: activation-sharding constraints + MoE mesh context, threaded
through the model forward.

Without explicit constraints GSPMD may resolve the FSDP-weight/batch-sharding
conflict at the lm_head by all-gathering the *batch* (observed: 13 GB logits
buffers with an unsharded 1M-token batch).  Pinning activations to
P(dp, None, None) and logits to P(dp, None, model) makes it gather the small
weight instead.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.moe import MoEShardingCtx


class ShardCtx(NamedTuple):
    mesh: object
    moe: Optional[MoEShardingCtx] = None
    act_spec: Optional[P] = None        # (B, S, D) activations
    logits_spec: Optional[P] = None     # (B, S, V) logits
    kv_spec: Optional[P] = None         # (B, S, Kv, Dh) attention K/V
    q_spec: Optional[P] = None          # (B, S, H, Dh) — set iff H % mesh == 0
    dp: Optional[tuple] = None          # data axes (None when batch unsharded)
    model_axis: str = "model"
    model_size: int = 1

    def act(self, x):
        if self.act_spec is None or x.ndim != 3:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.act_spec)
        )

    def logits(self, x):
        if self.logits_spec is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.logits_spec)
        )

    def kv(self, x):
        """Pin K/V before the blocked attention scan.  Without this, a
        seq-sharded prefill-cache out-sharding propagates backward into the
        scan and GSPMD computes every block rectangle redundantly on every
        model shard (observed 16x attention FLOPs on Mixtral prefill)."""
        if self.kv_spec is None or x.ndim != 4:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.kv_spec)
        )

    def q(self, x):
        if self.q_spec is None or x.ndim != 4:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.q_spec)
        )


def make_shard_ctx(mesh, dp_axes, model_axis: str, *, batch_sharded: bool,
                   moe: Optional[MoEShardingCtx] = None,
                   num_kv_heads: int = 0, num_heads: int = 0,
                   seq_parallel: bool = False,
                   act_shard_d: bool = False) -> ShardCtx:
    dp = dp_axes if batch_sharded else None
    # data-only meshes (the BFLC round engine's make_round_mesh) have no
    # model axis: treat it as size 1 and never name it in a spec
    msize = dict(mesh.shape).get(model_axis, 1)
    M = model_axis if model_axis in mesh.axis_names else None
    kv_heads_shardable = (M is not None and num_kv_heads > 0
                          and num_kv_heads % msize == 0)
    q_heads_shardable = (M is not None and num_heads > 0
                         and num_heads % msize == 0)
    return ShardCtx(
        mesh=mesh,
        moe=moe,
        act_spec=P(dp, M if seq_parallel else None,
                   M if act_shard_d and not seq_parallel else None),
        logits_spec=P(dp, None, M),
        kv_spec=P(dp, None, M if kv_heads_shardable else None, None),
        q_spec=(P(dp, None, M, None) if q_heads_shardable else None),
        dp=dp,
        model_axis=model_axis,
        model_size=msize,
    )
