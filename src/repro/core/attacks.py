"""Malicious-node attack models (paper §IV.C, §V.B).

* ``gaussian_perturbation`` — the paper's attack: pointwise Gaussian random
  noise replacing/corrupting the honest update.
* ``sign_flip`` / ``scaled_poison`` — extra attack modes (beyond-paper) to
  widen the robustness evaluation.
* ``CollusionPolicy`` — §V.B's strengthened attack: malicious committee
  members give random high scores (0.9–1.0) to malicious updates.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import numpy as np


def gaussian_perturbation(rng: np.random.Generator, update, sigma: float = 1.0,
                          ref=None):
    """Replace each coordinate with pointwise Gaussian noise.

    Noise is scaled per-leaf to ``ref``'s magnitude when given (the paper's
    regime: noise that rivals the *model*, poisoning the aggregate), else to
    the update's own magnitude (a stealthy norm-matched variant).  Local
    updates are tiny relative to the model, so update-scaled noise barely
    moves the global model — the ref=params scaling is what reproduces the
    Fig. 4 degradation."""
    leaves, treedef = jax.tree.flatten(update)
    ref_leaves = jax.tree.leaves(ref) if ref is not None else leaves
    out = []
    for leaf, rl in zip(leaves, ref_leaves):
        arr = np.asarray(leaf)
        scale = sigma * (np.abs(np.asarray(rl)).mean() + 1e-8)
        out.append(rng.normal(0.0, scale, arr.shape).astype(arr.dtype))
    return jax.tree.unflatten(treedef, out)


def sign_flip(update, scale: float = 1.0):
    return jax.tree.map(lambda x: -scale * x, update)


def scaled_poison(rng: np.random.Generator, update, target_scale: float = 10.0):
    """Boosted poisoning: huge step in a random direction."""
    leaves, treedef = jax.tree.flatten(update)
    out = []
    for leaf in leaves:
        arr = np.asarray(leaf)
        direction = rng.normal(0, 1, arr.shape).astype(arr.dtype)
        out.append(target_scale * np.abs(arr).mean() * direction)
    return jax.tree.unflatten(treedef, out)


@dataclass
class CollusionPolicy:
    """Malicious committee members' scoring behaviour (§V.B): random high
    scores for fellow-malicious updates, honest-looking scores otherwise."""

    high_lo: float = 0.9
    high_hi: float = 1.0

    def score(
        self,
        rng: np.random.Generator,
        member_is_malicious: bool,
        uploader_is_malicious: bool,
        honest_score: float,
    ) -> float:
        if member_is_malicious and uploader_is_malicious:
            return float(rng.uniform(self.high_lo, self.high_hi))
        if member_is_malicious and not uploader_is_malicious:
            # drag honest updates down (strongest collusion variant)
            return float(rng.uniform(0.0, 0.1))
        return honest_score


def poison_membership(manager, node_ids) -> None:
    """Re-point the community's malicious ground truth at exactly the given
    nodes.  Whole-group collusion scenarios (§V.B's strengthened attack
    applied to one hierarchical sub-committee: every trainer AND member of
    a slice colluding) re-mark the compromised set per round with this —
    everyone else reverts to honest."""
    target = {int(i) for i in node_ids}
    for nid, node in manager.nodes.items():
        node.is_malicious = nid in target


ATTACKS = {
    "gaussian": gaussian_perturbation,
    "sign_flip": lambda rng, u, **kw: sign_flip(u, **kw),
    "scaled": scaled_poison,
}
