"""Node management (paper §IV.A): alliance-chain permissioning in blacklist
mode, managed by the community's initial nodes (the managers)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set

import numpy as np


@dataclass
class Node:
    node_id: int
    data_indices: np.ndarray          # indices into the federated dataset
    is_malicious: bool = False        # ground-truth flag for simulation only
    tokens: float = 0.0               # incentive balance
    score_history: List[float] = field(default_factory=list)

    @property
    def latest_score(self) -> float:
        return self.score_history[-1] if self.score_history else 0.0


class NodeManager:
    """Blacklist-mode admission control + membership registry."""

    def __init__(self, permission_fee: float = 1.0):
        self.nodes: Dict[int, Node] = {}
        self.blacklist: Set[int] = set()
        self.permission_fee = permission_fee
        self.treasury = 0.0

    def join(self, node: Node) -> bool:
        """§IV.A: verification is blacklist-mode — rejected iff kicked before.
        Joining pays the permission fee into the managers' treasury."""
        if node.node_id in self.blacklist:
            return False
        node.tokens -= self.permission_fee
        self.treasury += self.permission_fee
        self.nodes[node.node_id] = node
        return True

    def leave(self, node_id: int) -> None:
        self.nodes.pop(node_id, None)

    def kick(self, node_id: int, reason: str = "misconduct") -> None:
        """Misconduct (misleading updates, model leaking) -> blacklist."""
        self.blacklist.add(node_id)
        self.nodes.pop(node_id, None)

    def active_ids(self) -> List[int]:
        return sorted(self.nodes)

    def sample_active(
        self, rng: np.random.Generator, proportion: float
    ) -> List[int]:
        """The paper's k%-active-nodes sampling: partial offline nodes never
        impede progress — only sampled nodes participate this round."""
        ids = self.active_ids()
        n = max(2, int(round(len(ids) * proportion)))
        return sorted(rng.choice(ids, size=min(n, len(ids)), replace=False).tolist())
