"""Security analysis of the committee mechanism (paper §IV.C, Fig. 3).

The conspiracy attack: A participating nodes, fraction q malicious, committee
fraction p.  The committee (A*p seats, performance assumed similar) is a
uniform draw without replacement, so the number of malicious seats X follows
Hypergeometric(A, A*q, A*p).  The attack succeeds iff X > A*p/2.

``attack_success_probability`` computes P[X > A*p/2] exactly in log space.
"""
from __future__ import annotations

import numpy as np
from math import lgamma


def _log_comb(n: int, k: int) -> float:
    if k < 0 or k > n:
        return -np.inf
    return lgamma(n + 1) - lgamma(k + 1) - lgamma(n - k + 1)


def hypergeom_pmf_log(A: int, K: int, n: int, x: int) -> float:
    """log P[X = x], X ~ Hypergeom(population A, successes K, draws n)."""
    return _log_comb(K, x) + _log_comb(A - K, n - x) - _log_comb(A, n)


def attack_success_probability(A: int, p: float, q: float) -> float:
    """P[malicious seats > half the committee] (Fig. 3)."""
    n = int(round(A * p))          # committee seats
    K = int(round(A * q))          # malicious nodes
    if n == 0:
        return 0.0
    threshold = n / 2.0
    xs = np.arange(int(np.floor(threshold)) + 1, n + 1)
    if len(xs) == 0:
        return 0.0
    logs = np.array([hypergeom_pmf_log(A, K, n, int(x)) for x in xs])
    # drop the x == threshold boundary when n even ("more than half")
    if n % 2 == 0 and xs[0] == threshold:
        logs = logs[1:]
    if len(logs) == 0:
        return 0.0
    m = logs.max()
    if m == -np.inf:
        return 0.0
    return float(np.exp(m) * np.exp(logs - m).sum())


def fig3_grid(A: int = 1000, ps=None, qs=None) -> dict:
    """The Fig. 3 surface: attack probability over (p, q)."""
    ps = ps if ps is not None else np.linspace(0.02, 0.5, 25)
    qs = qs if qs is not None else np.linspace(0.02, 0.98, 49)
    grid = np.zeros((len(ps), len(qs)))
    for i, p in enumerate(ps):
        for j, q in enumerate(qs):
            grid[i, j] = attack_success_probability(A, float(p), float(q))
    return {"A": A, "p": np.asarray(ps), "q": np.asarray(qs), "prob": grid}


def first_committee_honest_majority_invariant(q: float, p: float, A: int) -> float:
    """§IV.C induction argument: if the first committee has an honest
    majority, no malicious update is ever accepted (accepting needs > M/2
    colluding members, who could only have been seated by a previous
    malicious majority).  Returns the probability that a uniformly drawn
    first committee already has a malicious majority — the induction's only
    entry point."""
    return attack_success_probability(A, p, q)
