"""Committee election strategies (paper §IV.B).

A new committee is elected at the end of each round *from the providers of
validated updates* — committee members sit out training, so election also
rotates the validation set (the k-fold property of §III.B).
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

RANDOM = "random"
BY_SCORE = "by_score"
MULTI_FACTOR = "multi_factor"


def elect(
    method: str,
    rng: np.random.Generator,
    candidate_scores: Dict[int, float],
    committee_size: int,
    factors: Dict[int, float] | None = None,
    score_weight: float = 0.7,
) -> List[int]:
    """Returns the node ids of the next committee.

    candidate_scores: validated-update providers of this round -> median
    committee score of their update.
    factors: optional per-node secondary factor (e.g. network transmission
    rate) for MULTI_FACTOR.
    """
    if not candidate_scores:
        return []
    ids = np.array(sorted(candidate_scores))
    m = min(committee_size, len(ids))
    if method == RANDOM:
        # improves generalization; weaker against disguised malicious nodes
        return sorted(rng.choice(ids, size=m, replace=False).tolist())
    if method == BY_SCORE:
        # top validation scores: raises the cost of attack (paper's default)
        scores = np.array([candidate_scores[i] for i in ids])
        order = np.argsort(-scores, kind="stable")
        return sorted(ids[order[:m]].tolist())
    if method == MULTI_FACTOR:
        scores = np.array([candidate_scores[i] for i in ids], dtype=float)
        f = np.array([(factors or {}).get(i, 0.0) for i in ids], dtype=float)

        def norm(v):
            lo, hi = v.min(), v.max()
            return np.zeros_like(v) if hi == lo else (v - lo) / (hi - lo)

        combined = score_weight * norm(scores) + (1 - score_weight) * norm(f)
        order = np.argsort(-combined, kind="stable")
        return sorted(ids[order[:m]].tolist())
    raise ValueError(f"unknown election method {method!r}")
