"""The BFLC on-chain storage pattern (paper §III.A, Fig. 2).

Two block kinds on one alliance chain:

* **model block** at height ``t * period``   — the round-t global model;
* **update blocks** at heights ``[t*period+1, t*period+k]`` — the k scored
  local updates of round t.

The chain enforces this layout: exactly ``k`` update blocks must follow a
model block before the next model block may be appended.  The latest model is
addressable in O(1) (§III.A "nodes can get the latest model quickly").
Historical blocks exist for failure fallback & verification and can be pruned
(§IV.D) — pruning keeps headers (so hash-chain verification still works) and
drops payloads, or hands payloads to an off-chain store.

For hierarchical rounds (paper §V's network-sharding scale-out, built by
``repro.fl.hier``) a third kind exists: with ``tier2_block=True`` every
round additionally carries one **committee block** at height
``t*period + k + 1`` holding the tier-2 committee's decision record
(members, the S x Q2 score matrix over the sub-aggregates, accept mask).
The period then becomes ``k + 2``, the k update blocks store the S = k
sub-committee aggregates, and the committee block is part of the enforced
layout — a verified tiered chain cannot silently drop the tier-2 audit
trail.

Hashes are SHA-256 over (prev_hash, header fields, payload digest); payload
digests cover every leaf of the stored pytree, so a tampered weight flips the
chain — ``verify()`` catches it.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

MODEL = "model"
UPDATE = "update"
COMMITTEE = "committee"


def pytree_digest(tree: Any) -> str:
    h = hashlib.sha256()
    leaves, treedef = jax.tree.flatten(tree)
    h.update(str(treedef).encode())
    for leaf in leaves:
        arr = np.asarray(leaf)
        h.update(arr.dtype.str.encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


@dataclass
class Block:
    index: int
    kind: str                   # MODEL | UPDATE | COMMITTEE
    round: int
    prev_hash: str
    payload_digest: str
    # learning information (prunable; None after pruning)
    payload: Any = None
    # update-block fields (§III.A: uploader address + committee score)
    uploader: Optional[int] = None
    score: Optional[float] = None
    # block hash (filled on append)
    hash: str = ""
    pruned: bool = False
    # payload stored in the chain's codec format (e.g. int8 blob); decode
    # via Chain._payload, read raw via Chain.raw_payload
    encoded: bool = False

    def compute_hash(self) -> str:
        h = hashlib.sha256()
        h.update(self.prev_hash.encode())
        h.update(f"{self.index}|{self.kind}|{self.round}".encode())
        h.update(self.payload_digest.encode())
        h.update(f"{self.uploader}|{self.score}".encode())
        # the codec flag is part of the payload's interpretation: an
        # unauthenticated flip would make a verified chain decode (or not
        # decode) the stored blob differently
        h.update(f"{self.encoded}".encode())
        return h.hexdigest()


class LayoutError(RuntimeError):
    pass


class Chain:
    """The alliance-chain ledger for one BFLC training community."""

    def __init__(self, k_updates_per_round: int, off_chain_store=None,
                 update_codec=None, tier2_block: bool = False):
        if k_updates_per_round < 1:
            raise ValueError("k must be >= 1")
        self.k = k_updates_per_round
        # tiered rounds (repro.fl.hier): one committee block per round,
        # appended after the k sub-aggregate update blocks and before the
        # next model block — the layout makes the tier-2 audit trail
        # mandatory, not advisory
        self.tier2 = bool(tier2_block)
        self.blocks: List[Block] = []
        self._latest_model_idx: int = -1   # O(1) latest-model pointer
        self._latest_model_round: int = -1
        self.store = off_chain_store
        # optional payload codec for UPDATE blocks (paper §IV.D storage
        # optimization): encode() shrinks the on-chain blob (e.g. int8
        # quantization), decode() recovers the pytree.  Hashes cover the
        # *encoded* payload — that is what the chain stores and replicates.
        self.codec = update_codec

    # ------------------------------------------------------------------
    # layout arithmetic (paper §III.A)
    # ------------------------------------------------------------------
    @property
    def period(self) -> int:
        """Blocks per round: model + k updates (+ the tier-2 committee
        block on tiered chains)."""
        return self.k + 1 + (1 if self.tier2 else 0)

    def model_index(self, t: int) -> int:
        return t * self.period

    def update_index_range(self, t: int) -> Tuple[int, int]:
        return t * self.period + 1, t * self.period + self.k

    def committee_index(self, t: int) -> int:
        if not self.tier2:
            raise LayoutError("flat chain has no committee blocks "
                              "(construct with tier2_block=True)")
        return t * self.period + self.k + 1

    @property
    def height(self) -> int:
        return len(self.blocks)

    @property
    def current_round(self) -> int:
        """Round whose updates are currently being collected."""
        return self._latest_model_round

    # ------------------------------------------------------------------
    # appends
    # ------------------------------------------------------------------
    def _append(self, block: Block) -> Block:
        block.prev_hash = self.blocks[-1].hash if self.blocks else "genesis"
        block.hash = block.compute_hash()
        self.blocks.append(block)
        return block

    def append_model(self, model: Any, round_t: int) -> Block:
        expect = self.model_index(round_t)
        if self.height != expect:
            raise LayoutError(
                f"model block for round {round_t} must sit at height {expect}, "
                f"chain height is {self.height} (need {self.k} update blocks "
                f"per round)"
            )
        digest = pytree_digest(model)
        payload = model
        if self.store is not None:
            self.store.put(digest, model)
            payload = None
        blk = self._append(
            Block(
                index=self.height,
                kind=MODEL,
                round=round_t,
                prev_hash="",
                payload_digest=digest,
                payload=payload,
            )
        )
        self._latest_model_idx = blk.index
        self._latest_model_round = round_t
        return blk

    def append_update(
        self, update: Any, uploader: int, score: float, *,
        encoded: bool = False,
    ) -> Block:
        """Append one scored local update.  With a codec configured the
        payload is stored in codec format; pass ``encoded=True`` when the
        caller already encoded it (e.g. a whole round quantized in one
        kernel launch)."""
        if self._latest_model_idx < 0:
            raise LayoutError("no genesis model block yet")
        t = self._latest_model_round
        lo, hi = self.update_index_range(t)
        if not (lo <= self.height <= hi):
            raise LayoutError(
                f"round {t} already holds {self.k} updates; aggregate first"
            )
        if encoded and self.codec is None:
            raise ValueError(
                "encoded=True requires a Chain update_codec (nothing could "
                "decode the blob on read)"
            )
        if self.codec is not None and not encoded:
            update = self.codec.encode(update)
            encoded = True
        digest = pytree_digest(update)
        payload = update
        if self.store is not None:
            self.store.put(digest, update)
            payload = None
        return self._append(
            Block(
                index=self.height,
                kind=UPDATE,
                round=t,
                prev_hash="",
                payload_digest=digest,
                payload=payload,
                uploader=uploader,
                score=float(score),
                encoded=encoded,
            )
        )

    def append_committee(self, record: Any) -> Block:
        """Append the round's tier-2 committee block (tiered chains only).

        ``record`` is the committee's decision payload — members, the
        (S, Q2) sub-aggregate score matrix, accept mask.  It is stored
        verbatim (never codec-encoded: it is consensus metadata, not a
        model update) at the enforced height between the round's last
        update block and the next model block."""
        if self._latest_model_idx < 0:
            raise LayoutError("no genesis model block yet")
        t = self._latest_model_round
        expect = self.committee_index(t)       # raises on flat chains
        if self.height != expect:
            raise LayoutError(
                f"committee block for round {t} must sit at height {expect} "
                f"(after {self.k} update blocks), chain height is "
                f"{self.height}"
            )
        digest = pytree_digest(record)
        payload = record
        if self.store is not None:
            self.store.put(digest, record)
            payload = None
        return self._append(
            Block(
                index=self.height,
                kind=COMMITTEE,
                round=t,
                prev_hash="",
                payload_digest=digest,
                payload=payload,
            )
        )

    def updates_this_round(self) -> int:
        # clamp: on tiered chains the committee block also sits above the
        # latest model block but is not an update
        return min(self.height - 1 - self._latest_model_idx, self.k)

    def round_complete(self) -> bool:
        return self.updates_this_round() >= self.k

    # ------------------------------------------------------------------
    # reads
    # ------------------------------------------------------------------
    def raw_payload(self, blk: Block) -> Any:
        """Stored (possibly codec-encoded) payload — the fused aggregation
        path reads update blobs through here without dequantizing."""
        if blk.payload is not None:
            return blk.payload
        if self.store is not None:
            return self.store.get(blk.payload_digest)
        raise KeyError(f"block {blk.index} pruned and no off-chain store")

    def _payload(self, blk: Block) -> Any:
        raw = self.raw_payload(blk)
        if blk.encoded and self.codec is not None:
            return self.codec.decode(raw)
        return raw

    def latest_model(self) -> Tuple[int, Any]:
        """O(1): returns (round, model)."""
        if self._latest_model_idx < 0:
            raise LayoutError("empty chain")
        blk = self.blocks[self._latest_model_idx]
        return blk.round, self._payload(blk)

    def model_at_round(self, t: int) -> Any:
        """Failure fallback (§IV.C): recover any historical global model."""
        return self._payload(self.blocks[self.model_index(t)])

    def updates_at_round(self, t: int) -> List[Block]:
        lo, hi = self.update_index_range(t)
        return self.blocks[lo : min(hi, self.height - 1) + 1]

    def update_payloads_at_round(self, t: int, decode: bool = True) -> List[Any]:
        """Round-t update payloads; ``decode=False`` returns the stored
        codec-format blobs (the fused aggregation's input)."""
        return [
            self._payload(b) if decode else self.raw_payload(b)
            for b in self.updates_at_round(t)
        ]

    def committee_at_round(self, t: int) -> Any:
        """The round-t tier-2 committee decision record (tiered chains)."""
        idx = self.committee_index(t)
        if idx >= self.height:
            raise LayoutError(f"round {t} has no committee block yet")
        return self._payload(self.blocks[idx])

    # ------------------------------------------------------------------
    # integrity + storage optimization
    # ------------------------------------------------------------------
    def verify(self) -> bool:
        prev = "genesis"
        for blk in self.blocks:
            if blk.prev_hash != prev or blk.hash != blk.compute_hash():
                return False
            if blk.payload is not None and pytree_digest(blk.payload) != blk.payload_digest:
                return False
            # layout check: position within the round's period decides the
            # only kind allowed there
            pos = blk.index % self.period
            want = (MODEL if pos == 0
                    else UPDATE if pos <= self.k
                    else COMMITTEE)
            if blk.kind != want:
                return False
            prev = blk.hash
        return True

    def prune(self, keep_rounds: int = 1) -> int:
        """§IV.D: drop historical payloads, keep headers + latest rounds.

        Returns number of payloads dropped.  Verification of the hash chain
        remains possible (digests are in headers); payload recovery needs the
        off-chain store or an unpruned core node."""
        if self._latest_model_idx < 0:
            return 0
        cutoff_round = max(0, self._latest_model_round - keep_rounds + 1)
        cutoff_idx = self.model_index(cutoff_round)
        dropped = 0
        for blk in self.blocks[:cutoff_idx]:
            if blk.payload is not None:
                blk.payload = None
                blk.pruned = True
                dropped += 1
        return dropped

    def storage_bytes(self) -> int:
        """Approximate resident payload bytes (for §IV.D benchmarks)."""
        total = 0
        for blk in self.blocks:
            if blk.payload is not None:
                total += sum(
                    np.asarray(l).nbytes for l in jax.tree.leaves(blk.payload)
                )
        return total
