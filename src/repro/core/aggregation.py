"""Update aggregation strategies.

* ``fedavg``      — the Basic-FL baseline (McMahan et al.) and BFLC's own
  aggregation over committee-validated updates (weighted by sample counts or
  scores).
* ``cwmed``       — coordinate-wise median (Yin et al. 2018), the robust
  baseline of Fig. 4.
* ``trimmed_mean``— coordinate-wise trimmed mean (bonus robust baseline).

All operate on *flattened* update vectors (K, D); ``aggregate_pytrees``
adapts pytree updates.  The inner reductions dispatch to the Pallas kernels
(repro.kernels) when ``use_kernels=True`` — kernels are validated against
the jnp implementations here (their ref oracles import these).  For updates
already in the chain's quantized representation, ``aggregate_quantized_blobs``
feeds the fused int8 kernel directly — no f32 stack is ever materialized.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree


@jax.jit
def _flatten_stacked_leaves(leaves):
    """list of (K, ...) leaves -> (K, D) f32 in ravel_pytree leaf order."""
    return jnp.concatenate(
        [jnp.reshape(l, (l.shape[0], -1)).astype(jnp.float32) for l in leaves],
        axis=1,
    )


def flatten_updates(updates: Sequence) -> tuple:
    """Pytree updates -> (stacked (K, D) f32 matrix, unravel fn).

    One jitted flatten of the leaf-stacked pytree instead of K separate
    ``ravel_pytree`` traversals: XLA fuses the per-leaf reshape+concat into
    a single program, and the host-side pytree walk happens once."""
    if not updates:
        raise ValueError("no updates to flatten")
    _, unravel = ravel_pytree(updates[0])
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *updates)
    stack = _flatten_stacked_leaves(jax.tree.leaves(stacked))
    return stack, unravel


def normalize_weights(K: int, weights: Optional[jnp.ndarray]) -> jnp.ndarray:
    """(K,) unnormalized (or None -> uniform) -> (K,) f32 summing to 1.

    The single definition both aggregation paths share — the f32 einsum here
    and the fused int8 kernel path (repro.kernels.ops) must weigh committee
    scores identically."""
    w = (jnp.ones((K,), jnp.float32) if weights is None
         else jnp.asarray(weights).astype(jnp.float32))
    return w / jnp.maximum(w.sum(), 1e-12)


def fedavg(stack: jnp.ndarray, weights: Optional[jnp.ndarray] = None,
           use_kernels: bool = False) -> jnp.ndarray:
    """stack: (K, D); weights: (K,) unnormalized."""
    K = stack.shape[0]
    w = normalize_weights(K, weights)
    if use_kernels:
        from repro.kernels.ops import fedavg_agg
        return fedavg_agg(stack, w)
    return jnp.einsum("k,kd->d", w, stack)


def cwmed(stack: jnp.ndarray, use_kernels: bool = False) -> jnp.ndarray:
    """Coordinate-wise median over K updates."""
    if use_kernels:
        from repro.kernels.ops import cwmed as cwmed_kernel
        return cwmed_kernel(stack)
    return jnp.median(stack, axis=0)


def trimmed_mean(stack: jnp.ndarray, trim: int,
                 use_kernels: bool = False) -> jnp.ndarray:
    """Drop the `trim` largest and smallest per coordinate, mean the rest."""
    K = stack.shape[0]
    if not 0 <= 2 * trim < K:
        raise ValueError(f"trim={trim} invalid for K={K}")
    if use_kernels:
        from repro.kernels.ops import trimmed_mean as trimmed_mean_kernel
        return trimmed_mean_kernel(stack, trim=trim)
    s = jnp.sort(stack, axis=0)
    return s[trim : K - trim].mean(axis=0)


def aggregate_pytrees(
    updates: Sequence,
    method: str = "fedavg",
    weights: Optional[Sequence[float]] = None,
    trim: int = 1,
    use_kernels: bool = False,
):
    stack, unravel = flatten_updates(updates)
    w = None if weights is None else jnp.asarray(weights)
    if method == "fedavg":
        agg = fedavg(stack, w, use_kernels=use_kernels)
    elif method == "cwmed":
        agg = cwmed(stack, use_kernels=use_kernels)
    elif method == "trimmed_mean":
        agg = trimmed_mean(stack, trim, use_kernels=use_kernels)
    else:
        raise ValueError(method)
    return unravel(agg)


def aggregate_quantized_blobs(
    blobs: Sequence[dict],
    unravel,
    method: str = "fedavg",
    weights: Optional[Sequence[float]] = None,
    trim: int = 1,
):
    """Aggregate straight from K chain-format int8 blobs ({"q","scales","d"})
    via the fused Pallas pass — one int8 read, no f32 stack."""
    from repro.kernels.ops import aggregate_quantized

    q = jnp.stack([b["q"] for b in blobs])
    scales = jnp.stack([b["scales"] for b in blobs])
    d = int(blobs[0]["d"])
    w = None if weights is None else jnp.asarray(weights)
    flat = aggregate_quantized(q, scales, d, method=method, weights=w, trim=trim)
    return unravel(flat)


def apply_update(params, update, scale: float = 1.0):
    """params + scale * update (pytree add)."""
    return jax.tree.map(lambda p, u: p + scale * u.astype(p.dtype), params, update)
