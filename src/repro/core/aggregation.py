"""Update aggregation strategies.

* ``fedavg``      — the Basic-FL baseline (McMahan et al.) and BFLC's own
  aggregation over committee-validated updates (weighted by sample counts or
  scores).
* ``cwmed``       — coordinate-wise median (Yin et al. 2018), the robust
  baseline of Fig. 4.
* ``trimmed_mean``— coordinate-wise trimmed mean (bonus robust baseline).

All operate on *flattened* update vectors (K, D); ``aggregate_pytrees``
adapts pytree updates.  The inner reductions dispatch to the Pallas kernels
(repro.kernels) when ``use_kernels=True`` — kernels are validated against
the jnp implementations here (their ref oracles import these).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree


def flatten_updates(updates: Sequence) -> tuple:
    """Pytree updates -> (stacked (K, D) f32 matrix, unravel fn)."""
    flats = []
    unravel = None
    for u in updates:
        f, un = ravel_pytree(u)
        flats.append(f.astype(jnp.float32))
        unravel = un
    return jnp.stack(flats), unravel


def fedavg(stack: jnp.ndarray, weights: Optional[jnp.ndarray] = None,
           use_kernels: bool = False) -> jnp.ndarray:
    """stack: (K, D); weights: (K,) unnormalized."""
    K = stack.shape[0]
    w = jnp.ones((K,), jnp.float32) if weights is None else weights.astype(jnp.float32)
    w = w / jnp.maximum(w.sum(), 1e-12)
    if use_kernels:
        from repro.kernels.ops import fedavg_agg
        return fedavg_agg(stack, w)
    return jnp.einsum("k,kd->d", w, stack)


def cwmed(stack: jnp.ndarray, use_kernels: bool = False) -> jnp.ndarray:
    """Coordinate-wise median over K updates."""
    if use_kernels:
        from repro.kernels.ops import cwmed as cwmed_kernel
        return cwmed_kernel(stack)
    return jnp.median(stack, axis=0)


def trimmed_mean(stack: jnp.ndarray, trim: int) -> jnp.ndarray:
    """Drop the `trim` largest and smallest per coordinate, mean the rest."""
    K = stack.shape[0]
    if 2 * trim >= K:
        raise ValueError("trim too large")
    s = jnp.sort(stack, axis=0)
    return s[trim : K - trim].mean(axis=0)


def aggregate_pytrees(
    updates: Sequence,
    method: str = "fedavg",
    weights: Optional[Sequence[float]] = None,
    trim: int = 1,
    use_kernels: bool = False,
):
    stack, unravel = flatten_updates(updates)
    w = None if weights is None else jnp.asarray(weights)
    if method == "fedavg":
        agg = fedavg(stack, w, use_kernels=use_kernels)
    elif method == "cwmed":
        agg = cwmed(stack, use_kernels=use_kernels)
    elif method == "trimmed_mean":
        agg = trimmed_mean(stack, trim)
    else:
        raise ValueError(method)
    return unravel(agg)


def apply_update(params, update, scale: float = 1.0):
    """params + scale * update (pytree add)."""
    return jax.tree.map(lambda p, u: p + scale * u.astype(p.dtype), params, update)
