from repro.core.blockchain import Chain, Block, LayoutError, pytree_digest
from repro.core.consensus import CommitteeConsensus, consensus_cost
from repro.core.election import BY_SCORE, MULTI_FACTOR, RANDOM, elect
from repro.core.node import Node, NodeManager
from repro.core.security import attack_success_probability, fig3_grid

__all__ = [
    "Chain",
    "Block",
    "LayoutError",
    "pytree_digest",
    "CommitteeConsensus",
    "consensus_cost",
    "elect",
    "RANDOM",
    "BY_SCORE",
    "MULTI_FACTOR",
    "Node",
    "NodeManager",
    "attack_success_probability",
    "fig3_grid",
]
