"""Off-chain payload storage (paper §IV.D second scheme).

"The blockchain only maintains the network address where each model or
updated file is located" — here the address is the content digest and the
store is an in-process (optionally disk-backed) content-addressed KV.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional

from repro.checkpoint.ckpt import load_pytree, save_pytree


class OffChainStore:
    """Content-addressed store: digest -> pytree payload."""

    def __init__(self, directory: Optional[str] = None):
        self.directory = directory
        self._mem: Dict[str, Any] = {}
        if directory:
            os.makedirs(directory, exist_ok=True)

    def put(self, digest: str, payload: Any) -> None:
        if self.directory:
            save_pytree(os.path.join(self.directory, digest), payload)
        else:
            self._mem[digest] = payload

    def get(self, digest: str) -> Any:
        if self.directory:
            return load_pytree(os.path.join(self.directory, digest))
        return self._mem[digest]

    def __contains__(self, digest: str) -> bool:
        if self.directory:
            return os.path.exists(os.path.join(self.directory, digest))
        return digest in self._mem

    def size(self) -> int:
        if self.directory:
            return len(os.listdir(self.directory))
        return len(self._mem)
