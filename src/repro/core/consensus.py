"""Committee Consensus Mechanism — CCM (paper §III.B).

The committee validates each incoming local update *before* it is appended
to the chain (communication-based consensus).  Validation is the paper's
minimized approach: each member scores the update by the validation accuracy
on its own local data; the member scores are combined by **median** (robust
to a minority of colluding members).  Qualified updates (score above a
threshold policy) are packed as update blocks; when k accumulate, the
committee aggregates them into the next model block.

Message-cost accounting (paper §V.A): validating P trainer updates with a
committee of Q costs P*Q validations/messages, vs (P+Q)^2 for broadcast
consensus among all active nodes — `consensus_cost` exposes both for the
benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class ValidationRecord:
    uploader: int
    member_scores: Dict[int, float]       # committee member -> score
    median_score: float
    accepted: bool


@dataclass
class ConsensusStats:
    validations: int = 0                  # P*Q counter
    accepted: int = 0
    rejected: int = 0

    def broadcast_equivalent(self, active_nodes: int) -> int:
        return active_nodes * active_nodes


class CommitteeConsensus:
    """One round's committee: scores updates, decides acceptance."""

    def __init__(
        self,
        member_ids: Sequence[int],
        score_fn: Optional[Callable[[int, object], float]] = None,
        accept_threshold: float = 0.0,
        threshold_mode: str = "relative",   # "relative" | "absolute"
    ):
        """score_fn(member_id, update_payload) -> validation accuracy in [0,1].

        May be omitted when member scores are computed in one batched
        call *after* construction — bind them via ``bind_score_table``
        before the first ``validate``; an unbound consensus refuses to
        validate rather than silently scoring nothing.

        threshold_mode "relative": accept if median score >= accept_threshold
        * (running mean of accepted scores); "absolute": fixed cutoff.
        """
        self.member_ids = list(member_ids)
        self.score_fn = score_fn
        self.accept_threshold = accept_threshold
        self.threshold_mode = threshold_mode
        self.stats = ConsensusStats()
        self.records: List[ValidationRecord] = []
        self._accepted_scores: List[float] = []

    def bind_score_table(
        self, table: Dict[int, Dict[int, float]]
    ) -> None:
        """Score from a precomputed ``{uploader: {member: score}}`` matrix
        (e.g. the runtime's one-call vmapped P x Q accuracy matrix).

        Holds a *reference*: rows added to ``table`` after binding are
        visible, so a multi-cohort round binds once and keeps filling the
        table.  With a table bound, ``validate``'s ``update`` argument is
        the uploader id (the row key)."""
        self.score_fn = lambda member, uploader: table[uploader][member]

    def validate(self, uploader: int, update) -> ValidationRecord:
        if self.score_fn is None:
            raise ValueError(
                "CommitteeConsensus has no score_fn bound — pass score_fn "
                "at construction or call bind_score_table() first"
            )
        member_scores = {
            m: float(self.score_fn(m, update)) for m in self.member_ids
        }
        self.stats.validations += len(self.member_ids)
        median = float(np.median(list(member_scores.values())))
        accepted = self._accept(median)
        rec = ValidationRecord(uploader, member_scores, median, accepted)
        self.records.append(rec)
        if accepted:
            self.stats.accepted += 1
            self._accepted_scores.append(median)
        else:
            self.stats.rejected += 1
        return rec

    def _accept(self, median: float) -> bool:
        if self.threshold_mode == "absolute":
            return median >= self.accept_threshold
        if not self._accepted_scores:
            return True
        baseline = float(np.mean(self._accepted_scores))
        return median >= self.accept_threshold * baseline

    def accepted_records(self) -> List[ValidationRecord]:
        return [r for r in self.records if r.accepted]

    def candidate_scores(self) -> Dict[int, float]:
        """Validated-update providers -> score (election input, §IV.B)."""
        return {r.uploader: r.median_score for r in self.accepted_records()}


def consensus_cost(num_trainers: int, committee_size: int) -> Tuple[int, int]:
    """Returns (ccm_cost, broadcast_cost) = (P*Q, (P+Q)^2)  — paper §V.A."""
    P, Q = num_trainers, committee_size
    return P * Q, (P + Q) ** 2


def consensus_cost_tiered(num_trainers: int, tiers: int,
                          sub_committee_size: int,
                          committee_size: int) -> int:
    """Validation-message cost of a two-tier round (§V's network sharding).

    Each of the P trainers is validated by its slice's sub-committee of q
    members (P*q total across the S slices), then the S sub-aggregates are
    validated by the tier-2 committee of Q members — so the flat P*Q term
    drops to P*q + S*Q, with q fixed by the slice size rather than growing
    with the community."""
    return num_trainers * sub_committee_size + tiers * committee_size
