"""PBFT message accounting for the committee's block agreement.

The paper runs FISCO-BCOS with PBFT underneath the CCM.  The CCM reduces
*validation* cost to P·Q; the committee must still agree on each packed
block.  PBFT among Q committee members costs per consensus instance:

    pre-prepare: (Q-1)   prepare: Q(Q-1)   commit: Q(Q-1)
    total ≈ 2Q² - Q - 1 messages

BFLC runs one instance per packed block (k update blocks + 1 model block
per round), among Q members only.  Network-wide PBFT (the naive
decentralization the paper argues against) would run it among all A active
nodes.  `round_messages` exposes both so benchmarks/consensus_cost.py can
plot the full communication picture, not just validation counts.

Safety bound: PBFT tolerates f = floor((Q-1)/3) Byzantine members — the
committee additionally requires an honest majority (> Q/2) for median
scoring, so the binding constraint is the CCM's, matching §IV.C.
"""
from __future__ import annotations

from dataclasses import dataclass


def pbft_instance_messages(n: int) -> int:
    """Messages for one PBFT consensus among n replicas."""
    if n <= 1:
        return 0
    return (n - 1) + 2 * n * (n - 1)


def pbft_fault_tolerance(n: int) -> int:
    return max(0, (n - 1) // 3)


@dataclass
class RoundMessages:
    validation: int          # CCM: P updates x Q validators
    committee_pbft: int      # (k+1) blocks agreed among Q
    total_ccm: int
    network_pbft: int        # naive: (k+1) blocks agreed among all active


def round_messages(P: int, Q: int, k: int) -> RoundMessages:
    """Full per-round communication: CCM validation + committee PBFT vs
    network-wide PBFT."""
    validation = P * Q
    committee = (k + 1) * pbft_instance_messages(Q)
    network = (k + 1) * pbft_instance_messages(P + Q)
    return RoundMessages(
        validation=validation,
        committee_pbft=committee,
        total_ccm=validation + committee,
        network_pbft=network,
    )
