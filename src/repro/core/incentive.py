"""Incentive mechanism: *profit sharing by contribution* (paper §IV.A).

Permission fees fund the treasury (handled by NodeManager.join); after each
round's aggregation the managers distribute rewards proportional to the
committee scores of accepted updates.
"""
from __future__ import annotations

from typing import Dict

from repro.core.node import NodeManager


def distribute_rewards(
    manager: NodeManager,
    accepted_scores: Dict[int, float],
    pool: float,
) -> Dict[int, float]:
    """Splits `pool` tokens over uploaders proportionally to score.

    Returns the paid amounts.  Frequent, high-quality contributors earn more
    (the paper's virtuous circle)."""
    if not accepted_scores or pool <= 0:
        return {}
    total = sum(max(s, 0.0) for s in accepted_scores.values())
    paid = {}
    for node_id, score in accepted_scores.items():
        share = pool / len(accepted_scores) if total == 0 else pool * max(score, 0.0) / total
        node = manager.nodes.get(node_id)
        if node is not None:
            node.tokens += share
            paid[node_id] = share
    manager.treasury -= sum(paid.values())
    return paid
