from repro.checkpoint.ckpt import (
    is_quantized_blob,
    load_model_payload,
    load_pytree,
    save_pytree,
)

__all__ = [
    "is_quantized_blob",
    "load_model_payload",
    "load_pytree",
    "save_pytree",
]
