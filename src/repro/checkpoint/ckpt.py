"""msgpack pytree checkpointing (params, optimizer state, chain snapshots)."""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _encode_leaf(x):
    arr = np.asarray(x)
    # dtype by NAME: ml_dtypes types (bfloat16) stringify as void ('|V2')
    # through .str and would not round-trip
    return {
        b"__nd": True,
        b"dtype": arr.dtype.name.encode(),
        b"shape": list(arr.shape),
        b"data": arr.tobytes(),
    }


def _is_leaf_dict(d) -> bool:
    return isinstance(d, dict) and d.get(b"__nd") is True


def _decode_leaf(d):
    import ml_dtypes  # registers bfloat16 & friends with numpy  # noqa: F401

    arr = np.frombuffer(d[b"data"], dtype=np.dtype(d[b"dtype"].decode()))
    return jnp.asarray(arr.reshape(d[b"shape"]))


def save_pytree(path: str, tree: Any) -> None:
    leaves, treedef = jax.tree.flatten(tree)
    payload = {
        b"treedef": str(treedef).encode(),
        b"leaves": [_encode_leaf(l) for l in leaves],
        b"structure": _structure_of(tree),
    }
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def load_pytree(path: str, like: Any = None) -> Any:
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=True)
    leaves = [_decode_leaf(d) for d in payload[b"leaves"]]
    if like is not None:
        treedef = jax.tree.structure(like)
        return jax.tree.unflatten(treedef, leaves)
    return _rebuild(payload[b"structure"], iter(leaves))


def is_quantized_blob(tree: Any) -> bool:
    """True for an ``Int8UpdateCodec`` chain blob ({"q", "scales", "d"})."""
    return (
        isinstance(tree, dict)
        and set(tree.keys()) == {"q", "scales", "d"}
        and not isinstance(tree["d"], dict)
    )


def load_model_payload(path: str, codec: Any = None) -> Any:
    """Load a chain model snapshot: a raw parameter pytree, or — when the
    snapshot is an int8-codec chain blob and a codec is supplied — the
    decoded pytree.  The serving hot-swap path restores through here."""
    tree = load_pytree(path)
    if is_quantized_blob(tree):
        if codec is None:
            raise ValueError(
                f"{path} holds an int8 chain blob; pass the chain's "
                "Int8UpdateCodec to decode it"
            )
        # msgpack round-trips python ints as 0-d arrays; the dequantize
        # slice bound must be a concrete int
        tree = dict(tree, d=int(tree["d"]))
        return codec.decode(tree)
    return tree


def _structure_of(tree):
    """Serializable skeleton (dicts/lists/tuples/None markers).

    Dict keys are SORTED to match jax.tree.flatten leaf order."""
    if isinstance(tree, dict):
        return {b"__d": {str(k).encode(): _structure_of(tree[k])
                         for k in sorted(tree)}}
    if isinstance(tree, (list, tuple)):
        return {b"__l": [_structure_of(v) for v in tree],
                b"__t": isinstance(tree, tuple)}
    if tree is None:
        return {b"__n": True}
    return {b"__leaf": True}


def _rebuild(struct, leaves_iter):
    if b"__d" in struct:
        return {k.decode(): _rebuild(v, leaves_iter) for k, v in struct[b"__d"].items()}
    if b"__l" in struct:
        vals = [_rebuild(v, leaves_iter) for v in struct[b"__l"]]
        return tuple(vals) if struct[b"__t"] else vals
    if struct.get(b"__n"):
        return None
    return next(leaves_iter)
