"""Baselines the paper compares against (§V): Basic FL (FedAvg), CwMed, and
stand-alone centralized training.  Same client/local-training substrate as
BFLC so comparisons isolate the aggregation/consensus difference.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import aggregate_pytrees, apply_update
from repro.core.attacks import ATTACKS
from repro.data.synthetic import FederatedDataset
from repro.fl.adapter import ModelAdapter
from repro.fl.client import (
    make_eval_fn,
    make_local_train_fn,
    sample_client_batches,
)


@dataclass
class FLConfig:
    active_proportion: float = 0.1
    local_steps: int = 20
    local_batch: int = 32
    local_lr: float = 0.02
    momentum: float = 0.9
    aggregation: str = "fedavg"          # "fedavg" -> Basic FL; "cwmed" -> CwMed
    size_weighted: bool = True
    malicious_fraction: float = 0.0
    attack: str = "gaussian"
    attack_sigma: float = 1.0
    seed: int = 0


class FLTrainer:
    """Basic FL / CwMed: central-server aggregation, no validation."""

    def __init__(self, adapter: ModelAdapter, dataset: FederatedDataset,
                 cfg: FLConfig, initial_params=None):
        self.adapter = adapter
        self.data = dataset
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        n = dataset.num_clients
        self.malicious = set(
            self.rng.choice(
                n, int(round(cfg.malicious_fraction * n)), replace=False
            ).tolist()
        )
        self.params = (initial_params if initial_params is not None
                       else adapter.init(jax.random.PRNGKey(cfg.seed)))
        self._local_train = make_local_train_fn(adapter, cfg.local_lr, cfg.momentum)
        self._eval = make_eval_fn(adapter)
        self.accuracies: List[float] = []

    def evaluate(self) -> float:
        return self._eval(self.params, self.data.test_images, self.data.test_labels)

    def run_round(self):
        cfg, rng = self.cfg, self.rng
        n = self.data.num_clients
        m = max(2, int(round(n * cfg.active_proportion)))
        active = rng.choice(n, m, replace=False)

        pairs = [
            sample_client_batches(rng, self.data.client_images[i],
                                  self.data.client_labels[i],
                                  cfg.local_steps, cfg.local_batch)
            for i in active
        ]
        xs = np.stack([p[0] for p in pairs])
        ys = np.stack([p[1] for p in pairs])
        stacked = self._local_train(self.params, xs, ys)
        updates = [jax.tree.map(lambda x: x[i], stacked) for i in range(m)]
        attack = ATTACKS[cfg.attack]
        for idx, node in enumerate(active):
            if int(node) in self.malicious:
                updates[idx] = attack(
                    rng, updates[idx], cfg.attack_sigma, ref=self.params
                ) if cfg.attack == "gaussian" else attack(rng, updates[idx])

        weights = None
        if cfg.size_weighted and cfg.aggregation == "fedavg":
            weights = [len(self.data.client_labels[i]) for i in active]
        agg = aggregate_pytrees(updates, method=cfg.aggregation, weights=weights)
        self.params = apply_update(self.params, agg)

    def run(self, rounds: int, eval_every: int = 5) -> List[float]:
        for r in range(rounds):
            self.run_round()
            if (r + 1) % eval_every == 0 or r == rounds - 1:
                self.accuracies.append(self.evaluate())
        return self.accuracies


def train_standalone(
    adapter: ModelAdapter,
    dataset: FederatedDataset,
    *,
    steps: int,
    batch: int = 64,
    lr: float = 0.05,
    momentum: float = 0.9,
    seed: int = 0,
    eval_every: int = 200,
):
    """Centralized training on the merged dataset (paper's upper bound)."""
    rng = np.random.default_rng(seed)
    imgs, labels = dataset.merged_train()
    params = adapter.init(jax.random.PRNGKey(seed))
    evaluate = make_eval_fn(adapter)

    @jax.jit
    def step(p, mu, x, y):
        g = jax.grad(adapter.loss)(p, x, y)
        mu = jax.tree.map(lambda m, gg: momentum * m + gg, mu, g)
        p = jax.tree.map(lambda pp, m: pp - lr * m, p, mu)
        return p, mu

    mu = jax.tree.map(jnp.zeros_like, params)
    accs = []
    for s in range(steps):
        idx = rng.integers(0, len(labels), batch)
        params, mu = step(params, mu, imgs[idx], labels[idx])
        if (s + 1) % eval_every == 0 or s == steps - 1:
            accs.append(evaluate(params, dataset.test_images, dataset.test_labels))
    return params, accs
