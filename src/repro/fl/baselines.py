"""Baselines the paper compares against (§V): Basic FL (FedAvg), CwMed, and
stand-alone centralized training.  The federated baselines are the *same*
``repro.fl.pipeline`` round the BFLC runtime uses, with every committee
stage swapped for a no-op (uniform sampler, accept-all validator, pack-all
packer, no elector/rewarder) — BFLC-vs-baseline comparisons share one code
path, isolating the aggregation/consensus difference.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import FederatedDataset
from repro.fl.adapter import ModelAdapter
from repro.fl.client import make_eval_fn, make_local_train_fn
from repro.fl.pipeline import (
    RoundContext,
    baseline_stage_names,
    build_pipeline,
)


@dataclass
class FLConfig:
    active_proportion: float = 0.1
    local_steps: int = 20
    local_batch: int = 32
    local_lr: float = 0.02
    momentum: float = 0.9
    aggregation: str = "fedavg"          # "fedavg" -> Basic FL; "cwmed" -> CwMed
    size_weighted: bool = True
    malicious_fraction: float = 0.0
    attack: str = "gaussian"
    attack_sigma: float = 1.0
    seed: int = 0


class FLTrainer:
    """Basic FL / CwMed: central-server aggregation, no validation.

    The same stage pipeline as ``BFLCRuntime`` with the committee stages
    as no-ops; swap any stage via ``stages={kind: name-or-callable}``."""

    def __init__(self, adapter: ModelAdapter, dataset: FederatedDataset,
                 cfg: FLConfig, initial_params=None,
                 stages: Optional[Dict[str, object]] = None, mesh=None,
                 schedule: str = "sequential"):
        if schedule not in ("sequential", "async"):
            raise ValueError(
                f"schedule={schedule!r} must be 'sequential' or 'async'"
            )
        self.adapter = adapter
        self.data = dataset
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        n = dataset.num_clients
        self.malicious = set(
            self.rng.choice(
                n, int(round(cfg.malicious_fraction * n)), replace=False
            ).tolist()
        )
        self.params = (initial_params if initial_params is not None
                       else adapter.init(jax.random.PRNGKey(cfg.seed)))
        self._local_train = make_local_train_fn(adapter, cfg.local_lr, cfg.momentum)
        self._eval = make_eval_fn(adapter)
        self.mesh = mesh
        self._sharded_train = None
        if mesh is not None:
            from repro.fl.client import make_sharded_local_train_fn

            self._sharded_train = make_sharded_local_train_fn(
                adapter, cfg.local_lr, mesh, momentum=cfg.momentum
            )
        self.pipeline = build_pipeline(
            baseline_stage_names(cfg, mesh), stages, max_cohorts=1
        )
        self.schedule = schedule
        if schedule == "async":
            from repro.fl.async_engine import AsyncRoundPipeline

            self.pipeline = AsyncRoundPipeline.from_pipeline(self.pipeline)
        self.accuracies: List[float] = []
        self.stage_timings: List[Dict[str, float]] = []
        self._round = 0

    def evaluate(self) -> float:
        return self._eval(self.params, self.data.test_images, self.data.test_labels)

    def run_round(self):
        ctx = RoundContext(
            cfg=self.cfg,
            rng=self.rng,
            adapter=self.adapter,
            data=self.data,
            params=self.params,
            round=self._round,
            malicious=self.malicious,
            local_train_fn=self._local_train,
            mesh=self.mesh,
            sharded_train_fn=self._sharded_train,
        )
        self.pipeline.run(ctx)
        self.params = ctx.new_params
        self.stage_timings.append(dict(ctx.timings))
        self._round += 1

    def run(self, rounds: int, eval_every: int = 5) -> List[float]:
        for r in range(rounds):
            self.run_round()
            if (r + 1) % eval_every == 0 or r == rounds - 1:
                self.accuracies.append(self.evaluate())
        return self.accuracies


def train_standalone(
    adapter: ModelAdapter,
    dataset: FederatedDataset,
    *,
    steps: int,
    batch: int = 64,
    lr: float = 0.05,
    momentum: float = 0.9,
    seed: int = 0,
    eval_every: int = 200,
):
    """Centralized training on the merged dataset (paper's upper bound)."""
    rng = np.random.default_rng(seed)
    imgs, labels = dataset.merged_train()
    params = adapter.init(jax.random.PRNGKey(seed))
    evaluate = make_eval_fn(adapter)

    @jax.jit
    def step(p, mu, x, y):
        g = jax.grad(adapter.loss)(p, x, y)
        mu = jax.tree.map(lambda m, gg: momentum * m + gg, mu, g)
        p = jax.tree.map(lambda pp, m: pp - lr * m, p, mu)
        return p, mu

    mu = jax.tree.map(jnp.zeros_like, params)
    accs = []
    for s in range(steps):
        idx = rng.integers(0, len(labels), batch)
        params, mu = step(params, mu, imgs[idx], labels[idx])
        if (s + 1) % eval_every == 0 or s == steps - 1:
            accs.append(evaluate(params, dataset.test_images, dataset.test_labels))
    return params, accs
