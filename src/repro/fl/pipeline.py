"""Composable BFLC round pipeline (paper Fig. 1 as pluggable stages).

The paper's round is five distinct phases — sample, train,
committee-validate, aggregate-on-trigger, elect+reward — and the BFL
surveys (Wang & Hu 2021; Ma et al. 2020) taxonomize exactly these axes
(consensus, aggregation, incentive) as independently swappable.  This
module exposes the round that way:

* ``RoundContext`` threads one round's state (params, cohort, score
  table, packed records, chain, rng, per-stage timings) through the
  stages.
* Seven stage protocols — ``Sampler``, ``LocalTrainer``, ``Validator``,
  ``Packer``, ``Aggregator``, ``Elector``, ``Rewarder`` — each a plain
  callable ``(ctx) -> None`` with a string-keyed registry (the same
  idiom as ``repro.core.attacks.ATTACKS``).  Register a custom
  implementation with ``@register("aggregator", "my_impl")`` and name it
  when building a runtime; nothing inside this module needs editing.
* ``RoundPipeline`` drives the stages: sample/train/validate loop over
  cohorts until k qualified updates accumulate (the smart-contract
  trigger), then pack -> aggregate -> elect -> reward.  Every stage call
  is timed into ``ctx.timings`` (exported by ``benchmarks/round_bench``
  as ``BENCH_round.json``).

``BFLCRuntime`` is a thin facade over the default BFLC stage set;
``FLTrainer`` (Basic FL / CwMed) is the *same* pipeline with the
committee stages swapped for no-ops — baseline comparisons share one
code path.  The f32 (``pytree``) and fused-int8 (``fused_int8``)
aggregation engines are two registered ``Aggregator`` implementations;
the sharded multi-device engine (``local_sgd_sharded`` /
``top_k_int8_sharded`` / ``fused_int8_sharded``, in ``repro.fl.sharded``)
is exactly such a third set — registered stages, zero round-loop edits.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Protocol, Set

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import election as election_mod
from repro.core.aggregation import (
    aggregate_pytrees,
    apply_update,
    flatten_updates,
)
from repro.core.attacks import ATTACKS
from repro.core.consensus import CommitteeConsensus, ValidationRecord
from repro.core.incentive import distribute_rewards
from repro.fl.client import sample_client_batches


def _unstack(tree, n: int):
    return [jax.tree.map(lambda x: x[i], tree) for i in range(n)]


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


# ----------------------------------------------------------------------
# round state
# ----------------------------------------------------------------------
@dataclass
class RoundContext:
    """State threaded through one round's stage pipeline.

    Built fresh per round by the runtime facade; every stage reads what
    it needs and writes its products back.  ``manager``/``chain`` are
    optional so the committee-free baselines run through the same
    pipeline.
    """

    # round inputs
    cfg: Any                               # BFLCConfig or FLConfig (duck-typed)
    rng: np.random.Generator
    adapter: Any
    data: Any                              # FederatedDataset
    params: Any                            # latest global model pytree
    round: int
    manager: Any = None                    # NodeManager (None for baselines)
    chain: Any = None                      # Chain (None for baselines)
    round_committee: List[int] = field(default_factory=list)  # frozen at round start
    committee: List[int] = field(default_factory=list)        # elector's output
    q_committee: int = 0
    p_trainers: int = 0
    # jitted helpers (built once by the runtime, shared across rounds)
    local_train_fn: Any = None
    score_matrix_fn: Any = None
    collusion: Any = None                  # CollusionPolicy
    malicious: Optional[Set[int]] = None   # baseline ground truth (no manager)
    # sharded round engine (populated when the runtime was built with a
    # mesh; see repro.fl.sharded for the stages that consume these)
    mesh: Any = None                       # 1-D ("data",) device mesh
    sharded_train_fn: Any = None           # shard_mapped local-SGD program
    sharded_quantize_fn: Any = None        # per-shard int8 stack codec
    sharded_agg_fn: Any = None             # D-sharded fused int8 reducer
    sharded_score_fn: Any = None           # P-sharded score-matrix program
    int8_score_fn: Any = None              # fused int8 scorer (single device)
    sharded_int8_score_fn: Any = None      # P-sharded fused int8 scorer
    # hierarchical (two-tier) round state — HierState, built per round by
    # the runtime when cfg.tiers > 1 (see repro.fl.hier)
    hier: Any = None
    # uploader -> (q, scales, row, d): the int8 validators' per-row
    # chain-codec quantization, cached so the packer reuses the rows
    # instead of re-quantizing the packed stack
    row_quant: Dict[int, Any] = field(default_factory=dict)
    # per-cohort state (overwritten each cohort; the async engine stages
    # these between its cohort ring slots and the shared context)
    cohort: int = 0
    trainers: List[int] = field(default_factory=list)
    cohort_updates: List[Any] = field(default_factory=list)
    cohort_stacked: Any = None             # trainer's P-padded update stack
    cohort_poisoned: List[int] = field(default_factory=list)
    cohort_scores: Any = None              # validator's (P, Q) score matrix
    train_inflight: Any = None             # trainer's dispatched device stack
    # accumulated collection state
    trainers_total: List[int] = field(default_factory=list)
    updates: Dict[int, Any] = field(default_factory=dict)     # uploader -> update
    score_table: Dict[int, Dict[int, float]] = field(default_factory=dict)
    consensus: Optional[CommitteeConsensus] = None
    val_x: Any = None
    val_y: Any = None
    collected: bool = False                # k qualified updates reached
    # packed round output (Packer products)
    packed_ids: List[int] = field(default_factory=list)
    packed_scores: List[float] = field(default_factory=list)
    packed_updates: List[Any] = field(default_factory=list)
    packed_quantized: Any = None           # (q, scales, d, unravel) int8 stack
    weights: Any = None                    # aggregation weights (or None)
    # aggregation output
    aggregate: Any = None
    new_params: Any = None
    # incentive output
    rewards: Dict[int, float] = field(default_factory=dict)
    # per-stage wall-clock seconds (cumulative over cohorts)
    timings: Dict[str, float] = field(default_factory=dict)

    def is_malicious(self, node_id: int) -> bool:
        if self.manager is not None:
            return self.manager.nodes[node_id].is_malicious
        return self.malicious is not None and int(node_id) in self.malicious


# ----------------------------------------------------------------------
# stage protocols + registries
# ----------------------------------------------------------------------
class Stage(Protocol):
    def __call__(self, ctx: RoundContext) -> None: ...


class Sampler(Stage, Protocol):
    """Chooses ``ctx.trainers`` for the current cohort (empty = stop)."""


class LocalTrainer(Stage, Protocol):
    """Trains the cohort locally -> ``ctx.cohort_updates`` (may poison)."""


class Validator(Stage, Protocol):
    """Scores/admits the cohort's updates into ``ctx.updates`` and sets
    ``ctx.collected`` once the round's trigger condition is met.  May
    additionally define ``prepare(ctx)``, run once before cohort 0
    (e.g. to sample committee validation data)."""


class Packer(Stage, Protocol):
    """Selects the round's update set -> ``ctx.packed_*`` (+ chain update
    blocks, when a chain is present)."""


class Aggregator(Stage, Protocol):
    """Reduces the packed updates -> ``ctx.aggregate`` / ``ctx.new_params``
    (+ chain model block, when a chain is present)."""


class Elector(Stage, Protocol):
    """Seats the next committee -> ``ctx.committee``."""


class Rewarder(Stage, Protocol):
    """Distributes incentives and does end-of-round housekeeping."""


SAMPLERS: Dict[str, Sampler] = {}
LOCAL_TRAINERS: Dict[str, LocalTrainer] = {}
VALIDATORS: Dict[str, Validator] = {}
PACKERS: Dict[str, Packer] = {}
AGGREGATORS: Dict[str, Aggregator] = {}
ELECTORS: Dict[str, Elector] = {}
REWARDERS: Dict[str, Rewarder] = {}

REGISTRIES: Dict[str, Dict[str, Stage]] = {
    "sampler": SAMPLERS,
    "local_trainer": LOCAL_TRAINERS,
    "validator": VALIDATORS,
    "packer": PACKERS,
    "aggregator": AGGREGATORS,
    "elector": ELECTORS,
    "rewarder": REWARDERS,
}

STAGE_KINDS = tuple(REGISTRIES)

# keys under which RoundPipeline.run records wall clock in ctx.timings —
# the schema of BENCH_round.json rows (benchmarks/round_bench.py)
STAGE_TIMING_KEYS = (
    "sample", "train", "validate", "pack", "aggregate", "elect", "reward",
)


def register(kind: str, name: str) -> Callable[[Stage], Stage]:
    """Decorator: ``@register("aggregator", "sharded")`` adds a stage to
    its registry.  Re-registering a name overwrites (last wins), so
    notebooks and tests can iterate."""
    if kind not in REGISTRIES:
        raise ValueError(f"unknown stage kind {kind!r} (want one of {STAGE_KINDS})")

    def deco(obj: Stage) -> Stage:
        REGISTRIES[kind][name] = obj
        return obj

    return deco


def resolve(kind: str, impl) -> Stage:
    """Name -> registered stage; callables pass through unchanged."""
    if callable(impl):
        return impl
    registry = REGISTRIES[kind]
    if impl not in registry:
        raise KeyError(
            f"no {kind} named {impl!r}; registered: {sorted(registry)}"
        )
    return registry[impl]


def _sync_tree(ctx: RoundContext) -> list:
    """Every ctx field a stage may leave as in-flight device work.

    The sequential driver blocks on all of these after each stage so
    BENCH_round buckets measure their own compute: ``cohort_stacked`` /
    ``train_inflight`` catch the sharded trainer's async dispatch (which
    used to bleed into the validate bucket), ``cohort_scores`` the
    validator's score matrix, and a tiered round's ``sub_aggregates`` the
    per-slice fused reductions.  The async engine deliberately does NOT
    use this — it blocks only at true dependency edges."""
    sync = [ctx.cohort_updates, ctx.cohort_stacked, ctx.train_inflight,
            ctx.cohort_scores, ctx.packed_quantized, ctx.aggregate,
            ctx.new_params]
    if ctx.hier is not None:
        sync.append(ctx.hier.sub_aggregates)
    return sync


# ----------------------------------------------------------------------
# pipeline driver
# ----------------------------------------------------------------------
@dataclass
class RoundPipeline:
    """Ordered stage set for one round.

    ``run`` loops sample -> train -> validate over cohorts until the
    validator sets ``ctx.collected`` (k qualified updates — the paper's
    aggregation trigger) or ``max_cohorts`` is hit, then runs
    pack -> aggregate -> elect -> reward once.  Each stage call is timed
    into ``ctx.timings`` under its stage key."""

    sampler: Sampler
    local_trainer: LocalTrainer
    validator: Validator
    packer: Packer
    aggregator: Aggregator
    elector: Elector
    rewarder: Rewarder
    max_cohorts: int = 3

    def _timed(self, key: str, fn: Callable, ctx: RoundContext) -> None:
        t0 = time.perf_counter()
        fn(ctx)
        # jitted stages return asynchronously — block on the jax-carrying
        # ctx fields so each stage's compute lands in its own bucket
        # instead of bleeding into the next stage's first sync point
        jax.block_until_ready(_sync_tree(ctx))
        ctx.timings[key] = ctx.timings.get(key, 0.0) + (time.perf_counter() - t0)

    def run(self, ctx: RoundContext) -> RoundContext:
        # stage -> timing key: STAGE_TIMING_KEYS, the BENCH_round schema
        prepare = getattr(self.validator, "prepare", None)
        if prepare is not None:
            self._timed("validate", prepare, ctx)
        for cohort in range(self.max_cohorts):
            ctx.cohort = cohort
            # rows quantized for an earlier cohort describe that cohort's
            # updates — an uploader re-drawn later trains a NEW update, so
            # a surviving cache entry would put a stale blob on the chain.
            # The final cohort's cache still reaches the packer (no clear
            # runs after ``collected``); a multi-cohort round's packer
            # falls back to a fresh (bitwise-identical) re-quantize.
            ctx.row_quant.clear()
            self._timed("sample", self.sampler, ctx)
            if not ctx.trainers:
                break
            self._timed("train", self.local_trainer, ctx)
            self._timed("validate", self.validator, ctx)
            if ctx.collected:
                break
        self._timed("pack", self.packer, ctx)
        self._timed("aggregate", self.aggregator, ctx)
        self._timed("elect", self.elector, ctx)
        self._timed("reward", self.rewarder, ctx)
        return ctx


def default_stage_names(cfg, mesh=None) -> Dict[str, str]:
    """The BFLC wiring for a config: quantize_chain flips the packer +
    aggregator pair to the fused-int8 engine; a mesh flips local training
    and committee validation (and, when quantized, the packer + aggregator)
    to the sharded multi-device engine (repro.fl.sharded).  The sharded
    validator scores f32 in every config — it reproduces the single-device
    score matrix bit-for-bit; the quantized-view scorers
    (``committee_int8`` / ``committee_int8_sharded``) are opt-in via
    ``stages=`` because int8 scoring noise moves median scores."""
    quantized = bool(getattr(cfg, "quantize_chain", False))
    sharded = mesh is not None
    names = {
        "sampler": "active",
        "local_trainer": "local_sgd_sharded" if sharded else "local_sgd",
        "validator": "committee_sharded" if sharded else "committee",
        "packer": "top_k_int8" if quantized else "top_k",
        "aggregator": "fused_int8" if quantized else "pytree",
        "elector": "by_candidates",
        "rewarder": "proportional",
    }
    if sharded and quantized:
        names["packer"] = "top_k_int8_sharded"
        names["aggregator"] = "fused_int8_sharded"
    return names


def baseline_stage_names(cfg, mesh=None) -> Dict[str, str]:
    """Basic FL / CwMed: the same pipeline with every committee stage a
    no-op — one central aggregation over an unvalidated cohort."""
    return {
        "sampler": "uniform",
        "local_trainer": "local_sgd_sharded" if mesh is not None
        else "local_sgd",
        "validator": "accept_all",
        "packer": "all",
        "aggregator": "pytree",
        "elector": "none",
        "rewarder": "none",
    }


def build_pipeline(
    names: Dict[str, str],
    overrides: Optional[Dict[str, Any]] = None,
    max_cohorts: int = 3,
) -> RoundPipeline:
    """Stage names (+ optional per-kind overrides: a registered name or a
    bare callable) -> RoundPipeline."""
    import repro.fl.sharded  # noqa: F401  (registers the sharded stage set)

    merged = dict(names)
    if overrides:
        unknown = set(overrides) - set(STAGE_KINDS)
        if unknown:
            raise ValueError(
                f"unknown stage kinds {sorted(unknown)} (want {STAGE_KINDS})"
            )
        merged.update(overrides)
    return RoundPipeline(
        **{kind: resolve(kind, merged[kind]) for kind in STAGE_KINDS},
        max_cohorts=max_cohorts,
    )


# ----------------------------------------------------------------------
# default BFLC stages (paper Fig. 1)
# ----------------------------------------------------------------------
@register("sampler", "active")
def sample_active(ctx: RoundContext) -> None:
    """(1) k%-active sampling, committee excluded, topped up from the
    full membership when the draw comes in short (shape stability)."""
    cfg, rng = ctx.cfg, ctx.rng
    active = ctx.manager.sample_active(rng, cfg.active_proportion)
    trainers = [
        i for i in active
        if i not in ctx.round_committee and i not in ctx.updates
    ][: ctx.p_trainers]
    if len(trainers) < ctx.p_trainers:
        extra = [
            i for i in ctx.manager.active_ids()
            if i not in ctx.round_committee and i not in ctx.updates
            and i not in trainers
        ]
        need = min(ctx.p_trainers - len(trainers), len(extra))
        if need > 0:
            trainers += rng.choice(extra, size=need, replace=False).tolist()
    ctx.trainers = trainers


@register("sampler", "uniform")
def sample_uniform(ctx: RoundContext) -> None:
    """Baseline sampling: uniform draw over all clients, no committee to
    exclude; single cohort (a second call yields no new trainers)."""
    cfg, rng = ctx.cfg, ctx.rng
    if ctx.updates:
        ctx.trainers = []
        return
    n = ctx.data.num_clients
    m = max(2, int(round(n * cfg.active_proportion)))
    ctx.trainers = rng.choice(n, m, replace=False).tolist()


def sample_cohort_batches(ctx: RoundContext):
    """The cohort's stacked local batches: (P, steps, b, ...), (P, steps, b).

    One rng draw per trainer in ``ctx.trainers`` order — the single- and
    multi-device trainers share this so a fixed seed produces the same
    stream (the differential tests compare chain hashes)."""
    cfg, rng = ctx.cfg, ctx.rng
    pairs = [
        sample_client_batches(
            rng, ctx.data.client_images[i], ctx.data.client_labels[i],
            cfg.local_steps, cfg.local_batch,
        )
        for i in ctx.trainers
    ]
    return np.stack([p[0] for p in pairs]), np.stack([p[1] for p in pairs])


def poison_cohort_updates(ctx: RoundContext, updates: List[Any]) -> List[int]:
    """Per-node attack injection for malicious trainers (in place).

    Returns the poisoned indices (also recorded in ``ctx.cohort_poisoned``)
    so sharded validators know whether the trainer's device-resident update
    stack still matches the host-side update list."""
    cfg, rng = ctx.cfg, ctx.rng
    attack = ATTACKS[cfg.attack]
    poisoned = []
    for idx, node_id in enumerate(ctx.trainers):
        if ctx.is_malicious(node_id):
            updates[idx] = attack(
                rng, updates[idx], cfg.attack_sigma, ref=ctx.params
            ) if cfg.attack == "gaussian" else attack(rng, updates[idx])
            poisoned.append(idx)
    ctx.cohort_poisoned = poisoned
    return poisoned


class LocalSGDTrainer:
    """(2) cohort-batched local SGD (one vmapped XLA program) + per-node
    attack injection for malicious trainers.

    Split into ``dispatch`` (host rng batch draws + async XLA launch into
    ``ctx.train_inflight``) and ``finalize`` (unstack + attack injection)
    so the async engine can overlap cohort t+1's device compute with
    cohort t's host-side validate/pack work; ``__call__`` runs both
    back-to-back — the sequential engine is unchanged, op for op."""

    def dispatch(self, ctx: RoundContext) -> None:
        xs, ys = sample_cohort_batches(ctx)
        ctx.train_inflight = ctx.local_train_fn(ctx.params, xs, ys)
        ctx.cohort_stacked = None          # single-device: no sharded stack

    def finalize(self, ctx: RoundContext) -> None:
        stacked = ctx.train_inflight
        ctx.train_inflight = None
        updates = _unstack(stacked, len(ctx.trainers))
        poison_cohort_updates(ctx, updates)
        ctx.cohort_updates = updates

    def __call__(self, ctx: RoundContext) -> None:
        self.dispatch(ctx)
        self.finalize(ctx)


train_local_sgd = register("local_trainer", "local_sgd")(LocalSGDTrainer())


class CommitteeValidator:
    """(3) committee scoring: the P x Q accuracy matrix in one batched
    call, collusion overlay, median acceptance via CommitteeConsensus.

    ``prepare`` runs once per round: samples each member's validation
    batch and binds the (live) score table to the consensus object.
    ``_scores_device`` is the engine hook — subclasses swap in the
    sharded / fused-int8 score programs (repro.fl.sharded) without
    touching the consensus bookkeeping below.  ``dispatch`` launches the
    score program asynchronously (device result parked in
    ``ctx.cohort_scores``, no host rng consumed); ``finalize`` gathers it
    and runs the collusion overlay + consensus admissions; ``__call__``
    runs both back-to-back, so the sequential engine is unchanged."""

    # dispatch consumes no host rng (pure device launch) — the async
    # engine's rng-edge chaining reads this
    dispatch_uses_rng = False

    def prepare(self, ctx: RoundContext) -> None:
        cfg, rng = ctx.cfg, ctx.rng
        vpairs = [
            sample_client_batches(
                rng, ctx.data.client_images[j], ctx.data.client_labels[j],
                1, cfg.val_batch,
            )
            for j in ctx.round_committee
        ]
        ctx.val_x = np.stack([p[0][0] for p in vpairs])
        ctx.val_y = np.stack([p[1][0] for p in vpairs])
        ctx.consensus = CommitteeConsensus(
            ctx.round_committee, accept_threshold=cfg.accept_threshold
        )
        ctx.consensus.bind_score_table(ctx.score_table)

    def _scores_device(self, ctx: RoundContext):
        """The (rows >= P, Q) accuracy matrix of this cohort's candidates,
        as the score program's (possibly still in-flight) device result."""
        return ctx.score_matrix_fn(
            ctx.params, _stack(ctx.cohort_updates), ctx.val_x, ctx.val_y
        )

    def dispatch(self, ctx: RoundContext) -> None:
        ctx.cohort_scores = self._scores_device(ctx)

    def finalize(self, ctx: RoundContext) -> None:
        cfg, rng = ctx.cfg, ctx.rng
        # gather + drop padding rows (sharded scorers return >= P rows)
        honest_scores = np.asarray(ctx.cohort_scores)[: len(ctx.cohort_updates)]
        ctx.cohort_scores = honest_scores               # (P, Q)
        for i, uploader in enumerate(ctx.trainers):
            row = {}
            for j, member in enumerate(ctx.round_committee):
                s = float(honest_scores[i, j])
                if cfg.collusion:
                    s = ctx.collusion.score(
                        rng,
                        ctx.manager.nodes[member].is_malicious,
                        ctx.manager.nodes[uploader].is_malicious,
                        s,
                    )
                row[member] = s
            ctx.score_table[uploader] = row
        for idx, uploader in enumerate(ctx.trainers):
            ctx.consensus.validate(uploader, uploader)
            ctx.updates[uploader] = ctx.cohort_updates[idx]
        ctx.trainers_total += ctx.trainers
        # the paper's aggregation trigger: k QUALIFIED updates.  Packing
        # unqualified updates just to reach k would force one poisoned
        # update per round whenever honest trainers < k.
        if len(ctx.consensus.accepted_records()) >= cfg.k_updates:
            ctx.collected = True

    def __call__(self, ctx: RoundContext) -> None:
        self.dispatch(ctx)
        self.finalize(ctx)


register("validator", "committee")(CommitteeValidator())


def cache_row_quant(ctx: RoundContext, q, s, d: int) -> None:
    """Record the cohort's per-row chain-codec quantization on the context.

    ``q``/``s`` are the int8 scorer's (rows, Dpad) / (rows, nblk) arrays —
    the rows the committee just scored ARE the blobs a quantizing packer
    would store (identical tiling), so the packer stacks the cached rows
    instead of re-quantizing the packed updates.  Entries hold (array,
    array, row, d) references; the k packed rows are sliced at pack time."""
    for i, uploader in enumerate(ctx.trainers):
        ctx.row_quant[uploader] = (q, s, i, d)


def cached_row_stack(ctx: RoundContext, ids: Optional[List[int]] = None):
    """(q, s, d) stacked from the row-quant cache for the given uploaders
    (default: the packed set), or None when any row is missing (e.g. the
    default f32 validator ran — nothing was quantized yet, so there is
    nothing to reuse)."""
    ids = ctx.packed_ids if ids is None else ids
    cache = ctx.row_quant
    if not cache or any(u not in cache for u in ids):
        return None
    entries = [cache[u] for u in ids]
    q = jnp.stack([e[0][e[2]] for e in entries])
    s = jnp.stack([e[1][e[2]] for e in entries])
    return q, s, entries[0][3]


class Int8CommitteeValidator(CommitteeValidator):
    """Committee scoring straight from the chain-codec int8 view of each
    update (opt-in: ``stages={"validator": "committee_int8"}``): the fused
    Pallas pass rebuilds every candidate from its quantized row in one
    read, so the committee scores exactly the blob a quantizing packer
    would store.  Scores differ from the f32 validator by quantization
    noise only (tolerance-bounded in tests), so it is not the default —
    the default stays bit-compatible with the f32 oracle."""

    def _scores_device(self, ctx: RoundContext):
        if ctx.int8_score_fn is None:
            raise RuntimeError(
                "committee_int8 needs ctx.int8_score_fn — build the runtime "
                "with quantize_chain=True (the fused scorer shares the "
                "chain codec's unravel structure)"
            )
        stack, _ = flatten_updates(ctx.cohort_updates)
        scores, q, s = ctx.int8_score_fn(
            ctx.params, stack, ctx.val_x, ctx.val_y
        )
        cache_row_quant(ctx, q, s, int(stack.shape[1]))
        return scores


register("validator", "committee_int8")(Int8CommitteeValidator())


@register("validator", "accept_all")
def validate_accept_all(ctx: RoundContext) -> None:
    """Committee-free admission (Basic FL / CwMed): every update enters
    the round set unscored; one cohort satisfies the trigger."""
    for idx, uploader in enumerate(ctx.trainers):
        ctx.updates[int(uploader)] = ctx.cohort_updates[idx]
    ctx.trainers_total += [int(t) for t in ctx.trainers]
    ctx.collected = True


def _select_top_k(ctx: RoundContext) -> List[ValidationRecord]:
    """(3b) top-k qualified records; if the community could not produce k
    qualified updates (extreme malicious fractions), the best qualified
    one fills the remaining slots so the chain layout invariant holds
    (logged via duplicate uploader ids)."""
    cfg = ctx.cfg
    if ctx.consensus is None:
        raise RuntimeError(
            "top-k packers select from committee validation records — pair "
            "them with a consensus-producing validator (e.g. 'committee'), "
            "or swap in a score-free packer (e.g. 'all')"
        )
    records = sorted(
        ctx.consensus.accepted_records(), key=lambda r: -r.median_score
    )[: cfg.k_updates]
    if not records:  # nothing qualified: fall back to best available
        records = sorted(
            ctx.consensus.records, key=lambda r: -r.median_score
        )[:1]
    while len(records) < cfg.k_updates:
        records.append(records[0])
    return records


def _set_packed(ctx: RoundContext, records: List[ValidationRecord]) -> None:
    ctx.packed_ids = [r.uploader for r in records]
    ctx.packed_scores = [r.median_score for r in records]
    ctx.packed_updates = [ctx.updates[u] for u in ctx.packed_ids]
    ctx.weights = ctx.packed_scores if ctx.cfg.weight_by_score else None


@register("packer", "top_k")
def pack_top_k(ctx: RoundContext) -> None:
    """Packs the top-k qualified updates as f32 update blocks."""
    _set_packed(ctx, _select_top_k(ctx))
    for i, (u, sc) in enumerate(zip(ctx.packed_ids, ctx.packed_scores)):
        ctx.chain.append_update(ctx.packed_updates[i], u, sc)
        ctx.manager.nodes[u].score_history.append(sc)


@register("packer", "top_k_int8")
def pack_top_k_int8(ctx: RoundContext) -> None:
    """Quantized chain packing (paper §IV.D): flatten the packed cohort
    once, quantize the whole (K, D) stack in one kernel launch, store
    int8 blobs as update blocks, and hand the quantized stack to the
    fused aggregator — the f32 stack never hits HBM.  When an int8
    validator already quantized the round's rows, the cached rows are
    stacked instead (identical tiling — nothing is re-quantized)."""
    from repro.kernels.ops import quantize_stack

    _set_packed(ctx, _select_top_k(ctx))
    cached = cached_row_stack(ctx)
    if cached is not None:
        q, s, d = cached
        unravel = ctx.chain.codec.unravel
    else:
        stack, unravel = flatten_updates(ctx.packed_updates)
        q, s, d = quantize_stack(stack)
    for i, (u, sc) in enumerate(zip(ctx.packed_ids, ctx.packed_scores)):
        ctx.chain.append_update(
            {"q": q[i], "scales": s[i], "d": d}, u, sc, encoded=True
        )
        ctx.manager.nodes[u].score_history.append(sc)
    ctx.packed_quantized = (q, s, d, unravel)


@register("packer", "all")
def pack_all(ctx: RoundContext) -> None:
    """Baseline packing: every collected update, optionally size-weighted
    (classic FedAvg weighting); no chain, no scores."""
    cfg = ctx.cfg
    ctx.packed_ids = list(ctx.updates)
    ctx.packed_updates = [ctx.updates[u] for u in ctx.packed_ids]
    ctx.packed_scores = []
    weights = None
    if getattr(cfg, "size_weighted", False) and cfg.aggregation == "fedavg":
        weights = [len(ctx.data.client_labels[i]) for i in ctx.packed_ids]
    ctx.weights = weights


def _commit_aggregate(ctx: RoundContext, agg) -> None:
    ctx.aggregate = agg
    ctx.new_params = apply_update(ctx.params, agg)
    if ctx.chain is not None:
        ctx.chain.append_model(ctx.new_params, ctx.round + 1)


@register("aggregator", "pytree")
def aggregate_dense(ctx: RoundContext) -> None:
    """(4) dense aggregation over f32 update pytrees (jnp einsum/median,
    or the per-method Pallas kernels when cfg.use_kernels)."""
    cfg = ctx.cfg
    agg = aggregate_pytrees(
        ctx.packed_updates, method=cfg.aggregation, weights=ctx.weights,
        trim=getattr(cfg, "trim", 1),
        use_kernels=getattr(cfg, "use_kernels", False),
    )
    _commit_aggregate(ctx, agg)


@register("aggregator", "fused_int8")
def aggregate_fused_int8(ctx: RoundContext) -> None:
    """(4) fused one-pass aggregation straight from the chain's int8
    representation (one int8 read of the stack, dequant in-register)."""
    from repro.kernels.ops import aggregate_quantized

    cfg = ctx.cfg
    if ctx.packed_quantized is None:
        raise RuntimeError(
            "fused_int8 aggregator needs a quantizing packer (e.g. "
            "'top_k_int8') to stage the int8 stack in ctx.packed_quantized"
        )
    q, s, d, unravel = ctx.packed_quantized
    agg = unravel(aggregate_quantized(
        q, s, d, method=cfg.aggregation,
        weights=None if ctx.weights is None else jnp.asarray(ctx.weights),
        trim=cfg.trim,
    ))
    _commit_aggregate(ctx, agg)


def fill_committee(manager, committee: List[int], q_committee: int) -> List[int]:
    """Keep committee size exactly q_committee (shape stability).

    Backfill prefers nodes with the best score history (the managers'
    view of reputation) — random backfill re-opens the §IV.C induction
    to takeover whenever a round packs fewer candidates than q."""
    pool = [i for i in manager.active_ids() if i not in committee]
    pool.sort(key=lambda i: -manager.nodes[i].latest_score)
    committee = list(committee)
    while len(committee) < q_committee and pool:
        committee.append(pool.pop(0))
    return sorted(committee[:q_committee])


@register("elector", "by_candidates")
def elect_by_candidates(ctx: RoundContext) -> None:
    """(5) next committee from this round's validated providers (§IV.B);
    falls back to the sitting committee when no candidates packed."""
    cfg = ctx.cfg
    cand = dict(zip(ctx.packed_ids, ctx.packed_scores))
    elected = election_mod.elect(
        cfg.election_method, ctx.rng, cand, ctx.q_committee
    ) or list(ctx.round_committee)
    ctx.committee = fill_committee(ctx.manager, elected, ctx.q_committee)


@register("elector", "none")
def elect_none(ctx: RoundContext) -> None:
    """No election (baselines / static-committee ablations)."""


@register("rewarder", "proportional")
def reward_proportional(ctx: RoundContext) -> None:
    """(5) profit sharing by contribution (§IV.A) + end-of-round
    housekeeping: blacklist kicks and chain pruning."""
    cfg = ctx.cfg
    cand = dict(zip(ctx.packed_ids, ctx.packed_scores))
    ctx.rewards = distribute_rewards(ctx.manager, cand, cfg.reward_pool)
    if cfg.kick_below >= 0 and ctx.consensus is not None:
        for r in ctx.consensus.records:
            if r.median_score < cfg.kick_below:
                ctx.manager.kick(r.uploader)
    if cfg.prune_keep_rounds > 0:
        ctx.chain.prune(cfg.prune_keep_rounds)


@register("rewarder", "none")
def reward_none(ctx: RoundContext) -> None:
    """No incentive layer (baselines)."""
