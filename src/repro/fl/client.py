"""Client-side local training, batched across clients with vmap.

The paper's round has P trainers each running local SGD from the same global
model.  We stack all P clients' sampled batches into (P, steps, b, ...) and
``vmap`` the whole local-training loop — one XLA program trains every client
of the round at once (this is also exactly the structure the sharded
production path distributes over the mesh's data axis).

Committee validation is the same trick: the (P updates x Q members) accuracy
matrix — the P*Q cost term of §V.A — is one batched call, each candidate
model materialized once and evaluated on all Q member batches in a single
batched forward (and the same program shard_maps over the mesh's data axis
for the multi-device engine).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.adapter import ModelAdapter


def make_one_client_fn(adapter: ModelAdapter, lr: float, momentum: float = 0.0):
    """The single-client local-SGD program: (params, xs, ys) -> update.

    xs: (steps, batch, ...), ys: (steps, batch).  Both the vmapped
    single-device trainer and the shard_mapped multi-device trainer wrap
    exactly this function, so their per-client math is identical."""

    def one_client(params, xs, ys):
        def step(carry, xy):
            p, mu = carry
            x, y = xy
            g = jax.grad(adapter.loss)(p, x, y)
            mu = jax.tree.map(lambda m, gg: momentum * m + gg, mu, g)
            p = jax.tree.map(lambda pp, m: pp - lr * m, p, mu)
            return (p, mu), None

        mu0 = jax.tree.map(jnp.zeros_like, params)
        (final, _), _ = jax.lax.scan(step, (params, mu0), (xs, ys))
        return jax.tree.map(lambda a, b: a - b, final, params)

    return one_client


def make_local_train_fn(adapter: ModelAdapter, lr: float, momentum: float = 0.0):
    """Returns train(params, xs, ys) vmapped over a leading client axis.

    xs: (P, steps, batch, ...), ys: (P, steps, batch).  Output: update pytree
    stacked over P (update = locally-trained params - global params)."""
    one_client = make_one_client_fn(adapter, lr, momentum)
    return jax.jit(jax.vmap(one_client, in_axes=(None, 0, 0)))


def make_sharded_local_train_fn(adapter: ModelAdapter, lr: float, mesh,
                                momentum: float = 0.0, axis: str = "data"):
    """The P-client vmapped program shard_mapped over the mesh's data axis.

    Each device scans its (P / ndev)-client shard of the stacked batches
    (params replicated in, update stack sharded out over the leading client
    axis — one all-gather when the host unstacks).  The caller pads P to a
    multiple of the axis size; per-client results are independent, so the
    padded rows are sliced off without affecting real clients."""
    from jax.sharding import PartitionSpec as P

    from repro.shard_compat import shard_map

    vmapped = jax.vmap(make_one_client_fn(adapter, lr, momentum),
                       in_axes=(None, 0, 0))
    return jax.jit(shard_map(
        vmapped, mesh=mesh,
        in_specs=(P(), P(axis), P(axis)),
        out_specs=P(axis),
    ))


def _score_matrix_program(adapter: ModelAdapter):
    """The unjitted (params, updates, val_x, val_y) -> (P, Q) program.

    Per candidate i, ``params + update_i`` is materialized exactly once —
    hoisted out of the member axis — and all Q member val batches are
    evaluated in one batched forward on that shared candidate (the member
    vmap carries the data axis only; the weights stay unbatched, so XLA
    folds the Q batches into a single forward).  Both the single-device
    validator and the shard_mapped multi-device validator wrap exactly
    this function, so a P-shard's score rows are bitwise identical to the
    single-device oracle's."""

    def one_candidate(params, update, vx, vy):
        candidate = jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, update)
        return jax.vmap(adapter.accuracy, in_axes=(None, 0, 0))(candidate, vx, vy)

    def score(params, updates, vx, vy):
        return jax.vmap(one_candidate, in_axes=(None, 0, None, None))(
            params, updates, vx, vy
        )

    return score


def make_score_matrix_fn(adapter: ModelAdapter):
    """Returns score(params, updates, val_x, val_y) -> (P, Q) accuracies.

    updates: P-stacked pytree; val_x: (Q, vb, ...), val_y: (Q, vb).
    Entry [i, j] = accuracy of (global + update_i) on member j's data —
    the committee's minimized validation approach (§III.B)."""
    return jax.jit(_score_matrix_program(adapter))


def make_sharded_score_matrix_fn(adapter: ModelAdapter, mesh, axis: str = "data"):
    """The P x Q score-matrix program shard_mapped over the mesh's data axis.

    The update stack arrives P-sharded (each device scores its own
    candidate rows against the replicated params + member val batches);
    only the (P, Q) score matrix itself is gathered at the stage boundary
    — the candidate pytrees never leave their shard.  The caller pads P to
    a multiple of the axis size (mirroring the trainer's `_pad_clients`);
    score rows are independent, so padded rows are sliced off without
    affecting real candidates."""
    from jax.sharding import PartitionSpec as P

    from repro.shard_compat import shard_map

    return jax.jit(shard_map(
        _score_matrix_program(adapter), mesh=mesh,
        in_specs=(P(), P(axis), P(), P()),
        out_specs=P(axis),
    ))


def _int8_score_program(adapter: ModelAdapter, unravel, interpret: bool):
    """Unjitted (params, stack, vx, vy) -> ((rows, Q) scores, q, scales).

    ``stack``: (rows, D) f32 flattened updates.  Each row is quantized with
    the chain codec's tiling (so the committee scores exactly the int8 blob
    that would land on chain), then the fused Pallas pass rebuilds every
    candidate in one read — int8 row dequantized in-register and the delta
    applied during the base-parameter load — so the f32 (rows, D) candidate
    stack is materialized once, not twice (PR 1's fused-aggregation trick
    applied to validation).  The per-row ``(q, scales)`` come back with the
    scores: they ARE the chain blobs a quantizing packer would store, so
    the validator caches them on the RoundContext and the packer never
    re-quantizes (carried ROADMAP follow-up)."""
    from jax.flatten_util import ravel_pytree

    from repro.kernels.fused_score import make_fused_candidates_fn
    from repro.kernels.ops import _pad_to_block
    from repro.kernels.quantize import quantize_stack_kernel

    fused_candidates = make_fused_candidates_fn(interpret=interpret)

    def score(params, stack, vx, vy):
        D = stack.shape[1]
        q, s = quantize_stack_kernel(_pad_to_block(stack)[0],
                                     interpret=interpret)
        flat, _ = ravel_pytree(params)
        base = _pad_to_block(flat.astype(jnp.float32))[0]
        cands = fused_candidates(base, q, s)

        def one_candidate(row, vx, vy):
            candidate = unravel(row[:D])
            return jax.vmap(adapter.accuracy, in_axes=(None, 0, 0))(
                candidate, vx, vy
            )

        scores = jax.vmap(one_candidate, in_axes=(0, None, None))(
            cands, vx, vy
        )
        return scores, q, s

    return score


def flatten_stacked_updates(stacked):
    """In-program flatten of a P-stacked update pytree -> (P, D) f32.

    ``jax.tree.leaves`` order matches ``ravel_pytree`` (both walk the same
    treedef) and per-leaf ``reshape(P, -1)`` matches per-row C-order ravel,
    so row i equals ``ravel_pytree(update_i)`` bit-for-bit — the int8
    scorer can consume the trainer's device-resident ``ctx.cohort_stacked``
    without the host-side flatten round-trip (carried ROADMAP follow-up)."""
    leaves = jax.tree.leaves(stacked)
    return jnp.concatenate(
        [l.reshape(l.shape[0], -1).astype(jnp.float32) for l in leaves],
        axis=1,
    )


def make_score_from_int8_fn(adapter: ModelAdapter, unravel):
    """Single-device fused int8 scorer: (params, (P, D) stack, vx, vy) ->
    ((P, Q) accuracies of the quantized candidates, per-row q, scales)."""
    from repro.kernels.ops import _interpret

    return jax.jit(_int8_score_program(adapter, unravel, _interpret()))


def make_sharded_score_from_int8_fn(adapter: ModelAdapter, mesh, unravel,
                                    axis: str = "data"):
    """The fused int8 scorer shard_mapped over the mesh's data axis: each
    device flattens + quantizes + scores its own P-shard of the stacked
    update pytree (rows are tile-local, so per-row blobs — and therefore
    scores — are bitwise identical to the single-device int8 scorer); the
    (P, Q) score matrix and the P-sharded (q, scales) rows are gathered at
    the stage boundary.  Takes the trainer's stacked update pytree
    directly (``ctx.cohort_stacked`` stays device-resident, P-sharded on
    this mesh, zero relayout); the caller pads P to a multiple of the axis
    size."""
    from jax.sharding import PartitionSpec as P

    from repro.kernels.ops import _interpret
    from repro.shard_compat import shard_map

    program = _int8_score_program(adapter, unravel, _interpret())

    def score(params, stacked, vx, vy):
        return program(params, flatten_stacked_updates(stacked), vx, vy)

    return jax.jit(shard_map(
        score, mesh=mesh,
        in_specs=(P(), P(axis), P(), P()),
        out_specs=(P(axis), P(axis), P(axis)),
    ))


def make_eval_fn(adapter: ModelAdapter, eval_batch: int = 512):
    @jax.jit
    def _acc(params, x, y):
        return adapter.accuracy(params, x, y)

    def evaluate(params, images, labels) -> float:
        accs, n = [], len(labels)
        for i in range(0, n, eval_batch):
            accs.append(
                float(_acc(params, images[i : i + eval_batch], labels[i : i + eval_batch]))
                * min(eval_batch, n - i)
            )
        return sum(accs) / n

    return evaluate


def sample_client_batches(
    rng: np.random.Generator,
    images: np.ndarray,
    labels: np.ndarray,
    steps: int,
    batch: int,
) -> Tuple[np.ndarray, np.ndarray]:
    idx = rng.integers(0, len(labels), (steps, batch))
    return images[idx], labels[idx]
