"""Client-side local training, batched across clients with vmap.

The paper's round has P trainers each running local SGD from the same global
model.  We stack all P clients' sampled batches into (P, steps, b, ...) and
``vmap`` the whole local-training loop — one XLA program trains every client
of the round at once (this is also exactly the structure the sharded
production path distributes over the mesh's data axis).

Committee validation is the same trick: the (P updates x Q members) accuracy
matrix — the P*Q cost term of §V.A — is one nested-vmap call.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.adapter import ModelAdapter


def make_one_client_fn(adapter: ModelAdapter, lr: float, momentum: float = 0.0):
    """The single-client local-SGD program: (params, xs, ys) -> update.

    xs: (steps, batch, ...), ys: (steps, batch).  Both the vmapped
    single-device trainer and the shard_mapped multi-device trainer wrap
    exactly this function, so their per-client math is identical."""

    def one_client(params, xs, ys):
        def step(carry, xy):
            p, mu = carry
            x, y = xy
            g = jax.grad(adapter.loss)(p, x, y)
            mu = jax.tree.map(lambda m, gg: momentum * m + gg, mu, g)
            p = jax.tree.map(lambda pp, m: pp - lr * m, p, mu)
            return (p, mu), None

        mu0 = jax.tree.map(jnp.zeros_like, params)
        (final, _), _ = jax.lax.scan(step, (params, mu0), (xs, ys))
        return jax.tree.map(lambda a, b: a - b, final, params)

    return one_client


def make_local_train_fn(adapter: ModelAdapter, lr: float, momentum: float = 0.0):
    """Returns train(params, xs, ys) vmapped over a leading client axis.

    xs: (P, steps, batch, ...), ys: (P, steps, batch).  Output: update pytree
    stacked over P (update = locally-trained params - global params)."""
    one_client = make_one_client_fn(adapter, lr, momentum)
    return jax.jit(jax.vmap(one_client, in_axes=(None, 0, 0)))


def make_sharded_local_train_fn(adapter: ModelAdapter, lr: float, mesh,
                                momentum: float = 0.0, axis: str = "data"):
    """The P-client vmapped program shard_mapped over the mesh's data axis.

    Each device scans its (P / ndev)-client shard of the stacked batches
    (params replicated in, update stack sharded out over the leading client
    axis — one all-gather when the host unstacks).  The caller pads P to a
    multiple of the axis size; per-client results are independent, so the
    padded rows are sliced off without affecting real clients."""
    from jax.sharding import PartitionSpec as P

    from repro.shard_compat import shard_map

    vmapped = jax.vmap(make_one_client_fn(adapter, lr, momentum),
                       in_axes=(None, 0, 0))
    return jax.jit(shard_map(
        vmapped, mesh=mesh,
        in_specs=(P(), P(axis), P(axis)),
        out_specs=P(axis),
    ))


def make_score_matrix_fn(adapter: ModelAdapter):
    """Returns score(params, updates, val_x, val_y) -> (P, Q) accuracies.

    updates: P-stacked pytree; val_x: (Q, vb, ...), val_y: (Q, vb).
    Entry [i, j] = accuracy of (global + update_i) on member j's data —
    the committee's minimized validation approach (§III.B)."""

    def one(params, update, vx, vy):
        candidate = jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, update)
        return adapter.accuracy(candidate, vx, vy)

    over_members = jax.vmap(one, in_axes=(None, None, 0, 0))
    over_updates = jax.vmap(over_members, in_axes=(None, 0, None, None))
    return jax.jit(over_updates)


def make_eval_fn(adapter: ModelAdapter, eval_batch: int = 512):
    @jax.jit
    def _acc(params, x, y):
        return adapter.accuracy(params, x, y)

    def evaluate(params, images, labels) -> float:
        accs, n = [], len(labels)
        for i in range(0, n, eval_batch):
            accs.append(
                float(_acc(params, images[i : i + eval_batch], labels[i : i + eval_batch]))
                * min(eval_batch, n - i)
            )
        return sum(accs) / n

    return evaluate


def sample_client_batches(
    rng: np.random.Generator,
    images: np.ndarray,
    labels: np.ndarray,
    steps: int,
    batch: int,
) -> Tuple[np.ndarray, np.ndarray]:
    idx = rng.integers(0, len(labels), (steps, batch))
    return images[idx], labels[idx]
