from repro.fl.adapter import ModelAdapter, femnist_adapter
from repro.fl.baselines import FLConfig, FLTrainer, train_standalone
from repro.fl.runtime import BFLCConfig, BFLCRuntime

__all__ = [
    "ModelAdapter",
    "femnist_adapter",
    "FLConfig",
    "FLTrainer",
    "train_standalone",
    "BFLCConfig",
    "BFLCRuntime",
]
