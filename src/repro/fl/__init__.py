from repro.fl.adapter import ModelAdapter, femnist_adapter
from repro.fl.baselines import FLConfig, FLTrainer, train_standalone
from repro.fl.pipeline import (
    REGISTRIES,
    RoundContext,
    RoundPipeline,
    build_pipeline,
    register,
)
from repro.fl.runtime import BFLCConfig, BFLCRuntime, RoundLog

# the sharded multi-device stage set (repro.fl.sharded) registers itself
# when build_pipeline runs — no import needed here

__all__ = [
    "ModelAdapter",
    "femnist_adapter",
    "FLConfig",
    "FLTrainer",
    "train_standalone",
    "BFLCConfig",
    "BFLCRuntime",
    "RoundLog",
    "RoundContext",
    "RoundPipeline",
    "REGISTRIES",
    "build_pipeline",
    "register",
]
