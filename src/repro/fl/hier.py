"""Hierarchical committee rounds (paper §V's network-sharding scale-out).

BFLC's answer to "consensus cost explodes with the community" is to shard
the network: split a round's clients into S sub-communities, run committee
consensus inside each, and let a second-level committee judge the S
sub-results — the two-tier design the BFL surveys prescribe for
production-scale federations.  This module builds that as three registered
stages over the PR-2 pipeline (zero round-loop edits):

* ``sampler = "tiered"`` — partitions the round's active non-committee
  nodes into S slices, each with its own sub-committee (top-reputation
  members of the slice) and trainer set.  Slice s IS cohort s: the
  pipeline's existing cohort loop becomes the streaming ingest loop.
* ``validator = "hier"`` — per cohort/slice, swaps the round committee for
  the slice's sub-committee and delegates to an INNER validator (any
  registered one: ``committee``, ``committee_sharded``,
  ``committee_int8_sharded``, ...), so tier 1 reuses the PR-3/4 sharded
  fused engines unchanged.  After each slice it aggregates the accepted
  updates into one sub-aggregate (fused int8 when the chain is quantized:
  ``aggregate_quantized(..., quantize_out=True)`` yields the chain-ready
  blob in one pass) and then FREES the slice's update buffer — peak
  update-stack memory is bounded by the largest slice, never O(P·D).
* ``packer = "hier"`` — the tier-2 committee round: the round committee
  scores the S sub-aggregates with the same score-matrix engine tier 1
  used (sharded when a mesh is present), runs committee consensus over
  them (validated best-first, so a poisoned sub-aggregate — e.g. a fully
  colluding slice that passed its own tier-1 vote — fails the relative
  threshold against the honest majority of sub-aggregates), packs the
  accepted sub-aggregates as the round's update blocks and appends the
  tier-2 committee block (members, score matrix, accept mask) the tiered
  chain layout enforces.

``BFLCRuntime`` wires this up from ``cfg.tiers > 1``
(``build_runtime(..., tiers=S)``); ``tiers=1`` short-circuits to the flat
pipeline — the knob's identity element, bit-identical by construction.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import aggregate_pytrees, flatten_updates
from repro.core.consensus import CommitteeConsensus
from repro.fl.pipeline import (
    RoundContext,
    _stack,
    build_pipeline,
    cached_row_stack,
    default_stage_names,
    register,
    resolve,
)


@dataclass
class HierSlice:
    """One tier-1 sub-community: its trainers and its sub-committee."""

    index: int
    trainers: List[int]
    committee: List[int]


@dataclass
class HierState:
    """Per-round state of a tiered round, threaded via ``ctx.hier``.

    The runtime builds one per round (``cfg.tiers > 1``); stages fill it
    in.  ``peak_stack_bytes`` is the measured high-water mark of update
    stacks held at once — the quantity ``hier_bench`` reports against the
    O(P·D) flat equivalent (``flat_stack_bytes``)."""

    tiers: int
    inner_validator: Any
    dim: int = 0                           # flat update dimension D
    slices: List[HierSlice] = field(default_factory=list)
    # tier-1 products, one entry per processed slice
    sub_aggregates: List[Any] = field(default_factory=list)
    sub_blobs: List[Optional[dict]] = field(default_factory=list)
    sub_uploaders: List[int] = field(default_factory=list)
    sub_contributors: List[List[int]] = field(default_factory=list)
    t1_validations: int = 0
    # tier-2 inputs/outputs
    val_x2: Any = None
    val_y2: Any = None
    tier2_scores: Any = None               # (S, Q2) after pack
    # memory accounting
    peak_stack_bytes: int = 0
    flat_stack_bytes: int = 0
    max_slice_rows: int = 0
    # hier-validator slice bookkeeping between dispatch and finalize
    saved_committee: Any = None
    inner_split: bool = False

    def note_stack(self, nbytes: int) -> None:
        self.peak_stack_bytes = max(self.peak_stack_bytes, int(nbytes))


def _require_hier(ctx: RoundContext, stage: str) -> HierState:
    if ctx.hier is None:
        raise RuntimeError(
            f"{stage} needs ctx.hier — build the runtime with tiers >= 2 "
            "(build_runtime(..., tiers=S))"
        )
    return ctx.hier


def _tree_nbytes(tree) -> int:
    # .nbytes covers np and (without a host copy) jax arrays; scalar
    # leaves (a blob's "d") fall back through np.asarray
    return int(sum(getattr(l, "nbytes", None) or np.asarray(l).nbytes
                   for l in jax.tree.leaves(tree)))


def _slice_stack_nbytes(ctx: RoundContext) -> int:
    """Bytes of the update stack currently buffered for this slice (the
    device-resident padded stack when the sharded trainer ran, else the
    host-side update list)."""
    if ctx.cohort_stacked is not None:
        return _tree_nbytes(ctx.cohort_stacked)
    if not ctx.cohort_updates:
        return 0
    return len(ctx.cohort_updates) * _tree_nbytes(ctx.cohort_updates[0])


# ----------------------------------------------------------------------
# tier-1 sampler: slice the round into sub-communities
# ----------------------------------------------------------------------
def _partition_round(ctx: RoundContext, st: HierState) -> None:
    cfg, rng = ctx.cfg, ctx.rng
    S = st.tiers
    active = ctx.manager.sample_active(rng, cfg.active_proportion)
    committee = set(ctx.round_committee)
    pool = [i for i in active if i not in committee]
    # each slice needs a >= 3-member sub-committee (median robustness,
    # same floor as the runtime's q_committee) plus at least one trainer
    if len(pool) < 4 * S:
        raise ValueError(
            f"tiers={S} needs at least {4 * S} active non-committee nodes "
            f"for 3-member sub-committees + trainers, have {len(pool)}"
        )
    order = [int(x) for x in rng.permutation(np.asarray(pool, dtype=np.int64))]
    base = len(order) // S
    q_sub = min(max(3, int(round(base * cfg.committee_fraction))), base - 1)
    bounds = np.linspace(0, len(order), S + 1).astype(int)
    slices = []
    for s in range(S):
        members = order[bounds[s]:bounds[s + 1]]
        # slice sub-committee: the slice's top-reputation members (the
        # managers' view — mirrors fill_committee's backfill ranking)
        ranked = sorted(members,
                        key=lambda i: -ctx.manager.nodes[i].latest_score)
        sub_committee = sorted(ranked[:q_sub])
        trainers = [i for i in members if i not in set(sub_committee)]
        slices.append(HierSlice(s, trainers, sub_committee))
        st.max_slice_rows = max(st.max_slice_rows, len(trainers))
    st.slices = slices


@register("sampler", "tiered")
def sample_tiered(ctx: RoundContext) -> None:
    """(1, tiered) cohort s = slice s: the whole active set is partitioned
    into S sub-communities once per round (cohort 0), then each cohort
    trains exactly one slice — the pipeline's cohort loop is the streaming
    ingest loop."""
    st = _require_hier(ctx, "tiered sampler")
    if ctx.cohort == 0:
        _partition_round(ctx, st)
    ctx.trainers = (st.slices[ctx.cohort].trainers
                    if ctx.cohort < len(st.slices) else [])


# async-engine scheduling contract (repro.fl.async_engine): the partition
# is frozen at cohort 0, so slice s+1's trainer list depends only on the
# sampler having run for slice s — NOT on slice s's validation — and
# ``collected`` is shape-static (always the last slice).  That lets the
# async executor prefetch-sample and train slice s+1 while slice s is
# still being scored/sub-aggregated.  rng is drawn at cohort 0 only.
sample_tiered.prefetch_safe = True
sample_tiered.rng_first_only = True


# ----------------------------------------------------------------------
# tier-1 validator: per-slice committee consensus + sub-aggregation
# ----------------------------------------------------------------------
def _aggregate_slice(ctx: RoundContext, ids: List[int],
                     weights: Optional[List[float]]):
    """Reduce one slice's accepted updates to a sub-aggregate.

    Quantized chains: the fused int8 pass emits the chain-ready blob
    directly (``quantize_out=True``) — reusing the validator's cached
    per-row (q, scales) when the inner validator was an int8 one.
    Returns (sub_aggregate pytree, blob-or-None)."""
    cfg = ctx.cfg
    # slices are smaller than the flat round's k_updates; clamp the trim
    # so trimmed_mean stays well-defined per slice
    trim = min(getattr(cfg, "trim", 1), (len(ids) - 1) // 2)
    w = None if weights is None else jnp.asarray(weights)
    if getattr(cfg, "quantize_chain", False):
        from repro.kernels.ops import aggregate_quantized, quantize_stack

        cached = cached_row_stack(ctx, ids)
        if cached is not None:
            q, s, d = cached
        else:
            stack, _ = flatten_updates([ctx.updates[u] for u in ids])
            q, s, d = quantize_stack(stack)
        bq, bs, _ = aggregate_quantized(
            q, s, d, method=cfg.aggregation, weights=w, trim=trim,
            quantize_out=True,
        )
        blob = {"q": bq, "scales": bs, "d": d}
        # tier 2 scores (and the chain stores) exactly this blob — decode
        # it so downstream consumers see the stored content, bit-for-bit
        return ctx.chain.codec.decode(blob), blob
    sub = aggregate_pytrees(
        [ctx.updates[u] for u in ids], method=cfg.aggregation,
        weights=weights, trim=trim,
        use_kernels=getattr(cfg, "use_kernels", False),
    )
    return sub, None


class HierValidator:
    """(3, tiered) the tier-1 driver: per slice, swap in the slice's
    sub-committee, delegate scoring + consensus to the INNER validator
    (any registered validator — the sharded/fused engines run unchanged),
    reduce the accepted updates to one sub-aggregate, then free the slice
    buffer.  Only one slice's update stack is ever alive."""

    def prepare(self, ctx: RoundContext) -> None:
        st = _require_hier(ctx, "hier validator")
        cfg, rng = ctx.cfg, ctx.rng
        from repro.fl.client import sample_client_batches

        # tier-2 validation data: one batch per round-committee member,
        # drawn up front (slice loops must not perturb the draw order
        # relative to how many slices ran)
        vpairs = [
            sample_client_batches(
                rng, ctx.data.client_images[j], ctx.data.client_labels[j],
                1, cfg.val_batch,
            )
            for j in ctx.round_committee
        ]
        st.val_x2 = np.stack([p[0][0] for p in vpairs])
        st.val_y2 = np.stack([p[1][0] for p in vpairs])

    # dispatch swaps in the slice sub-committee and runs the inner
    # validator's prepare (which draws the slice's val batches) — the
    # async engine's rng-edge chaining must order it with the host rng
    # stream
    dispatch_uses_rng = True

    def dispatch(self, ctx: RoundContext) -> None:
        """Open slice ``ctx.cohort``: swap in its sub-committee, reset the
        slice-scoped dicts, run the inner validator's prepare + dispatch
        (score program launched, result in flight).  Between dispatch and
        finalize the async engine only runs trainer/sampler nodes, which
        touch none of the slice-scoped state — validator nodes themselves
        are serialized (finalize s before dispatch s+1)."""
        st = _require_hier(ctx, "hier validator")
        sl = st.slices[ctx.cohort]
        st.note_stack(_slice_stack_nbytes(ctx))
        st.saved_committee = ctx.round_committee
        ctx.round_committee = sl.committee
        ctx.score_table = {}
        ctx.updates = {}
        ctx.row_quant = {}
        ctx.consensus = None
        try:
            inner = st.inner_validator
            prep = getattr(inner, "prepare", None)
            if prep is not None:
                prep(ctx)
            inner_dispatch = getattr(inner, "dispatch", None)
            st.inner_split = inner_dispatch is not None
            if st.inner_split:
                inner_dispatch(ctx)
            else:
                inner(ctx)                  # monolithic inner validator
        except BaseException:
            self._close_slice(ctx, st)
            raise

    def finalize(self, ctx: RoundContext) -> None:
        st = _require_hier(ctx, "hier validator")
        try:
            if st.inner_split:
                st.inner_validator.finalize(ctx)
            self._finish_slice(ctx, st)
        finally:
            self._close_slice(ctx, st)
        # the inner validator's k-updates trigger does not apply: a tiered
        # round ingests every slice exactly once
        ctx.collected = ctx.cohort >= len(st.slices) - 1

    @staticmethod
    def _close_slice(ctx: RoundContext, st: HierState) -> None:
        ctx.round_committee = st.saved_committee
        # streaming ingest: drop every reference to this slice's
        # update stack before the next slice lands — THE memory bound
        ctx.updates = {}
        ctx.cohort_updates = []
        ctx.cohort_stacked = None
        ctx.cohort_scores = None
        ctx.row_quant = {}
        ctx.score_table = {}

    def __call__(self, ctx: RoundContext) -> None:
        self.dispatch(ctx)
        self.finalize(ctx)

    def _finish_slice(self, ctx: RoundContext, st: HierState) -> None:
        cfg = ctx.cfg
        if ctx.consensus is not None:
            recs = sorted(ctx.consensus.accepted_records(),
                          key=lambda r: -r.median_score)
            if not recs:  # nothing qualified: best available (layout holds)
                recs = sorted(ctx.consensus.records,
                              key=lambda r: -r.median_score)[:1]
            ids = [r.uploader for r in recs]
            weights = ([r.median_score for r in recs]
                       if cfg.weight_by_score else None)
            st.t1_validations += ctx.consensus.stats.validations
        else:  # consensus-free inner validator (e.g. accept_all)
            ids = list(ctx.updates)
            weights = None
        sub, blob = _aggregate_slice(ctx, ids, weights)
        st.sub_aggregates.append(sub)
        st.sub_blobs.append(blob)
        st.sub_uploaders.append(ids[0])    # top-scored contributor = rep
        st.sub_contributors.append(ids)


register("validator", "hier")(HierValidator())


# ----------------------------------------------------------------------
# tier-2 packer: committee consensus over the S sub-aggregates
# ----------------------------------------------------------------------
def _tier2_scores(ctx: RoundContext, st: HierState) -> np.ndarray:
    """(S, Q2) accuracy matrix of the sub-aggregates on the round
    committee's validation batches — the same engine tier 1 used, sharded
    over the mesh when one is present."""
    stacked = _stack(st.sub_aggregates)
    n = len(st.sub_aggregates)
    if ctx.mesh is not None and ctx.sharded_score_fn is not None:
        from repro.fl.sharded import _pad_rows

        ndev = dict(ctx.mesh.shape).get("data", ctx.mesh.devices.size)
        scores = ctx.sharded_score_fn(
            ctx.params, _pad_rows(stacked, n, ndev), st.val_x2, st.val_y2
        )
    else:
        scores = ctx.score_matrix_fn(
            ctx.params, stacked, st.val_x2, st.val_y2
        )
    return np.asarray(scores)[:n]


@register("packer", "hier")
def pack_hier(ctx: RoundContext) -> None:
    """(3b/tier 2) second-level committee round over the sub-aggregates,
    then the tiered chain commit: S update blocks (the sub-aggregates,
    int8 blobs on quantized chains) + the committee block.

    Sub-aggregates are validated in descending-median order: committee
    members see all S candidates at once (they are S blocks, not a
    stream), so the consensus threshold anchors on the best sub-aggregate
    — a poisoned one (whole slice colluding at tier 1) scores far below
    the honest majority and fails the relative threshold, which is the
    per-tier attack filtering a flat committee cannot provide."""
    st = _require_hier(ctx, "hier packer")
    cfg, rng = ctx.cfg, ctx.rng
    S = len(st.sub_aggregates)
    honest = _tier2_scores(ctx, st)                     # (S, Q2)
    st.tier2_scores = honest
    st.note_stack(S * st.dim * 4 + sum(
        _tree_nbytes(b) for b in st.sub_blobs if b is not None
    ))
    st.flat_stack_bytes = len(ctx.trainers_total) * st.dim * 4

    t2 = CommitteeConsensus(ctx.round_committee,
                            accept_threshold=cfg.accept_threshold)
    table: Dict[int, Dict[int, float]] = {}
    t2.bind_score_table(table)
    rep_slice: Dict[int, int] = {}
    medians = []
    for s_idx in range(S):
        rep = st.sub_uploaders[s_idx]
        rep_slice[rep] = s_idx
        row = {}
        for j, member in enumerate(ctx.round_committee):
            sc = float(honest[s_idx, j])
            if cfg.collusion:
                sc = ctx.collusion.score(
                    rng,
                    ctx.is_malicious(member),
                    ctx.is_malicious(rep),
                    sc,
                )
            row[member] = sc
        table[rep] = row
        medians.append(float(np.median(list(row.values()))))
    for s_idx in sorted(range(S), key=lambda i: -medians[i]):
        t2.validate(st.sub_uploaders[s_idx], st.sub_uploaders[s_idx])
    # total message cost of the round: P*q_sub at tier 1 + S*Q2 here
    # (consensus_cost_tiered in repro.core.consensus) — RoundLog reads it
    # off this consensus object
    t2.stats.validations += st.t1_validations

    recs = sorted(t2.accepted_records(), key=lambda r: -r.median_score)
    if not recs:
        recs = sorted(t2.records, key=lambda r: -r.median_score)[:1]
    recs = recs[:S]
    while len(recs) < S:                   # duplicate-fill: layout needs S
        recs.append(recs[0])

    ctx.consensus = t2
    ctx.packed_ids = [r.uploader for r in recs]
    ctx.packed_scores = [r.median_score for r in recs]
    packed_slices = [rep_slice[r.uploader] for r in recs]
    ctx.packed_updates = [st.sub_aggregates[i] for i in packed_slices]
    ctx.weights = ctx.packed_scores if cfg.weight_by_score else None

    quantized = bool(getattr(cfg, "quantize_chain", False))
    for r, s_idx in zip(recs, packed_slices):
        if quantized:
            ctx.chain.append_update(st.sub_blobs[s_idx], r.uploader,
                                    r.median_score, encoded=True)
        else:
            ctx.chain.append_update(st.sub_aggregates[s_idx], r.uploader,
                                    r.median_score)
        ctx.manager.nodes[r.uploader].score_history.append(r.median_score)
    ctx.chain.append_committee({
        "members": np.asarray(ctx.round_committee, np.int64),
        "uploaders": np.asarray(st.sub_uploaders, np.int64),
        "scores": np.asarray(honest, np.float32),
        "medians": np.asarray(medians, np.float32),
        "accepted": np.asarray(
            [any(r.uploader == st.sub_uploaders[i] and r.accepted
                 for r in t2.records) for i in range(S)]
        ),
    })
    if quantized:
        # stage the packed blobs for the fused aggregators — same
        # (q, scales, d, unravel) contract as the flat int8 packers
        q = jnp.stack([st.sub_blobs[i]["q"] for i in packed_slices])
        s = jnp.stack([st.sub_blobs[i]["scales"] for i in packed_slices])
        d = int(st.sub_blobs[packed_slices[0]]["d"])
        if ctx.mesh is not None:
            from repro.fl.sharded import _pad_cached_to_shards

            ndev = dict(ctx.mesh.shape).get("data", ctx.mesh.devices.size)
            q, s = _pad_cached_to_shards(q, s, d, ndev)
        ctx.packed_quantized = (q, s, d, ctx.chain.codec.unravel)


def build_hier_pipeline(cfg, mesh=None, overrides=None):
    """The tiered stage set for a config: tiered sampler + hier validator
    + hier packer over the flat defaults, with the config's trainer and
    aggregator untouched.  A ``validator`` override selects the INNER
    (tier-1, per-slice) validator; other overrides replace stages as
    usual.  Returns (pipeline, inner_validator) — the runtime threads the
    inner validator to the hier stages via ``HierState``."""
    overrides = dict(overrides or {})
    names = default_stage_names(cfg, mesh)
    inner_name = overrides.pop("validator", names["validator"])
    names.update({"sampler": "tiered", "validator": "hier",
                  "packer": "hier"})
    pipeline = build_pipeline(names, overrides, max_cohorts=cfg.tiers)
    return pipeline, resolve("validator", inner_name)
