"""The BFLC round loop (paper Fig. 1): the decentralized runtime that ties
chain + committee consensus + election + incentive together.

Each round:
  (1) active nodes are sampled (k% participation; offline nodes never block),
  (2) trainers (active minus committee) locally train from the latest model
      block and submit updates to the committee,
  (3) the committee scores every update on its own local data (median over
      members), packs the top-k qualified updates as update blocks,
  (4) the smart-contract trigger fires at k updates: the committee aggregates
      them into the next model block,
  (5) a new committee is elected from this round's validated providers, and
      rewards are distributed by contribution.

Malicious behaviour (Gaussian-perturbation updates, collusive scoring) is
injected per §V.B when configured.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import election as election_mod
from repro.core.aggregation import (
    aggregate_pytrees,
    apply_update,
    flatten_updates,
)
from repro.core.attacks import ATTACKS, CollusionPolicy
from repro.core.blockchain import Chain
from repro.core.consensus import CommitteeConsensus
from repro.core.incentive import distribute_rewards
from repro.core.node import Node, NodeManager
from repro.data.synthetic import FederatedDataset
from repro.fl.adapter import ModelAdapter
from repro.fl.client import (
    make_eval_fn,
    make_local_train_fn,
    make_score_matrix_fn,
    sample_client_batches,
)


@dataclass
class BFLCConfig:
    active_proportion: float = 0.1
    committee_fraction: float = 0.4      # fraction of active nodes
    k_updates: int = 8                   # update blocks per round (chain k)
    local_steps: int = 20
    local_batch: int = 32
    local_lr: float = 0.02
    momentum: float = 0.9
    val_batch: int = 64
    election_method: str = election_mod.BY_SCORE
    accept_threshold: float = 0.5        # relative threshold (consensus stat)
    aggregation: str = "fedavg"
    trim: int = 1                        # trimmed_mean drop count per side
    weight_by_score: bool = True
    use_kernels: bool = False
    # store update blocks as int8 blobs (paper §IV.D) and aggregate straight
    # from the quantized chain representation via the fused Pallas pass —
    # one int8 read of the stack, no f32 (K, D) materialization.
    quantize_chain: bool = False
    malicious_fraction: float = 0.0
    attack: str = "gaussian"
    attack_sigma: float = 1.0
    collusion: bool = True
    kick_below: float = -1.0             # blacklist uploaders under this score
    # §IV.C's induction assumes the FIRST committee has an honest majority —
    # the managers' initial trusted set (§IV.A).  True = bootstrap round-0
    # committee from manager-vetted (non-malicious) nodes; False = uniform
    # random (the conspiracy scenario of Fig. 3).
    honest_bootstrap: bool = True
    prune_keep_rounds: int = 0           # >0: prune old payloads each round
    reward_pool: float = 10.0
    seed: int = 0


@dataclass
class RoundLog:
    round: int
    trainers: int
    committee: int
    accepted_malicious: int
    packed_malicious: int
    mean_packed_score: float
    consensus_validations: int
    test_accuracy: Optional[float] = None


def _unstack(tree, n: int):
    return [jax.tree.map(lambda x: x[i], tree) for i in range(n)]


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


class BFLCRuntime:
    def __init__(
        self,
        adapter: ModelAdapter,
        dataset: FederatedDataset,
        cfg: BFLCConfig,
        initial_params=None,
    ):
        if cfg.quantize_chain and not cfg.use_kernels:
            # the quantized chain path IS the fused Pallas engine; there is
            # no jnp fallback for it, so refuse the contradictory config
            # rather than silently overriding the use_kernels switch
            raise ValueError(
                "quantize_chain=True requires use_kernels=True "
                "(aggregation runs the fused Pallas int8 path)"
            )
        if cfg.aggregation == "trimmed_mean" and not (
            0 <= 2 * cfg.trim < cfg.k_updates
        ):
            # validate up front: by round time the update blocks are already
            # on the chain, and a failed aggregation would strand the round
            # mid-layout
            raise ValueError(
                f"trim={cfg.trim} invalid for k_updates={cfg.k_updates} "
                f"(need 0 <= 2*trim < k_updates)"
            )
        self.adapter = adapter
        self.data = dataset
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)

        # node community: blacklist-mode manager, malicious ground truth
        self.manager = NodeManager()
        n = dataset.num_clients
        mal = set(
            self.rng.choice(
                n, int(round(cfg.malicious_fraction * n)), replace=False
            ).tolist()
        )
        for i in range(n):
            self.manager.join(
                Node(node_id=i, data_indices=np.arange(len(dataset.client_labels[i])),
                     is_malicious=i in mal)
            )

        # chain + genesis model block (#0: randomly initialized model, or a
        # warm start — new communities may bootstrap from an existing model)
        params = (initial_params if initial_params is not None
                  else adapter.init(jax.random.PRNGKey(cfg.seed)))
        self._codec = None
        if cfg.quantize_chain:
            from repro.kernels.ops import Int8UpdateCodec

            self._codec = Int8UpdateCodec(params)
        self.chain = Chain(cfg.k_updates, update_codec=self._codec)
        self.chain.append_model(params, 0)

        # jitted batched helpers
        self._local_train = make_local_train_fn(adapter, cfg.local_lr, cfg.momentum)
        self._score_matrix = make_score_matrix_fn(adapter)
        self._eval = make_eval_fn(adapter)
        self._collusion = CollusionPolicy()

        # fixed per-round sizes: keeps XLA programs shape-stable (one compile).
        # Committee size >= 3: the median of two scores is their mean, so a
        # single colluding member controls it (observed takeover cascade in a
        # scaled-down Fig. 4 run with q=2 — the paper's own setting is q=18).
        n_active = max(2, int(round(n * cfg.active_proportion)))
        self.q_committee = max(3, int(round(n_active * cfg.committee_fraction)))
        self.p_trainers = max(cfg.k_updates, n_active - self.q_committee)

        # round-0 committee: no scores exist yet.  With honest_bootstrap the
        # managers seat their initial trusted nodes (the paper's §IV.C
        # precondition); otherwise uniform random — in which case a malicious
        # population q close to 1/2 can seat a colluding majority with the
        # Fig. 3 hypergeometric probability and take over permanently.
        active = self.manager.sample_active(self.rng, cfg.active_proportion)
        pool = active
        if cfg.honest_bootstrap:
            honest = [i for i in active
                      if not self.manager.nodes[i].is_malicious]
            pool = honest or active
        self.committee: List[int] = sorted(
            self.rng.choice(pool, min(self.q_committee, len(pool)),
                            replace=False).tolist()
        )
        self._fill_committee()
        self.logs: List[RoundLog] = []

    def _fill_committee(self):
        """Keep committee size exactly q_committee (shape stability).

        Backfill prefers nodes with the best score history (the managers'
        view of reputation) — random backfill re-opens the §IV.C induction
        to takeover whenever a round packs fewer candidates than q."""
        pool = [i for i in self.manager.active_ids() if i not in self.committee]
        pool.sort(key=lambda i: -self.manager.nodes[i].latest_score)
        while len(self.committee) < self.q_committee and pool:
            self.committee.append(pool.pop(0))
        self.committee = sorted(self.committee[: self.q_committee])

    # ------------------------------------------------------------------
    def global_params(self):
        return self.chain.latest_model()[1]

    def evaluate(self) -> float:
        return self._eval(self.global_params(), self.data.test_images,
                          self.data.test_labels)

    # ------------------------------------------------------------------
    def run_round(self, eval_test: bool = False) -> RoundLog:
        cfg, rng = self.cfg, self.rng
        t, params = self.chain.latest_model()

        committee = [i for i in self.committee if i in self.manager.nodes]

        # committee validation data (fixed per round)
        vpairs = [
            sample_client_batches(
                rng, self.data.client_images[j], self.data.client_labels[j],
                1, cfg.val_batch,
            )
            for j in committee
        ]
        vx = np.stack([p[0][0] for p in vpairs])
        vy = np.stack([p[1][0] for p in vpairs])

        consensus = CommitteeConsensus(
            committee,
            score_fn=None,  # bound per cohort below
            accept_threshold=cfg.accept_threshold,
        )

        # Nodes submit updates until k QUALIFIED updates accumulate (the
        # paper's aggregation trigger).  Packing unqualified updates just to
        # reach k would force one poisoned update per round whenever honest
        # trainers < k — the takeover leak found in testing.
        all_updates: Dict[int, object] = {}
        trainers_total: List[int] = []
        attack = ATTACKS[cfg.attack]
        for cohort in range(3):   # at most 3 cohorts per round (sim bound)
            active = self.manager.sample_active(rng, cfg.active_proportion)
            trainers = [
                i for i in active
                if i not in committee and i not in all_updates
            ][: self.p_trainers]
            if len(trainers) < self.p_trainers:
                extra = [
                    i for i in self.manager.active_ids()
                    if i not in committee and i not in all_updates
                    and i not in trainers
                ]
                need = min(self.p_trainers - len(trainers), len(extra))
                if need > 0:
                    trainers += rng.choice(
                        extra, size=need, replace=False
                    ).tolist()
            if not trainers:
                break

            # (2) local training, batched over the cohort
            pairs = [
                sample_client_batches(
                    rng, self.data.client_images[i],
                    self.data.client_labels[i],
                    cfg.local_steps, cfg.local_batch,
                )
                for i in trainers
            ]
            xs = np.stack([p[0] for p in pairs])
            ys = np.stack([p[1] for p in pairs])
            updates_stacked = self._local_train(params, xs, ys)
            updates = _unstack(updates_stacked, len(trainers))
            for idx, node_id in enumerate(trainers):
                if self.manager.nodes[node_id].is_malicious:
                    updates[idx] = attack(
                        rng, updates[idx], cfg.attack_sigma, ref=params
                    ) if cfg.attack == "gaussian" else attack(rng, updates[idx])

            # (3) committee validation: the P x Q score matrix in one call
            honest_scores = np.asarray(
                self._score_matrix(params, _stack(updates), vx, vy)
            )                                               # (P, Q)
            score_table: Dict[int, Dict[int, float]] = {}
            for i, uploader in enumerate(trainers):
                row = {}
                for j, member in enumerate(committee):
                    s = float(honest_scores[i, j])
                    if cfg.collusion:
                        s = self._collusion.score(
                            rng,
                            self.manager.nodes[member].is_malicious,
                            self.manager.nodes[uploader].is_malicious,
                            s,
                        )
                    row[member] = s
                score_table[uploader] = row
            consensus.score_fn = lambda m, payload: score_table[payload][m]
            for idx, uploader in enumerate(trainers):
                consensus.validate(uploader, uploader)
                all_updates[uploader] = updates[idx]
            trainers_total += trainers
            if len(consensus.accepted_records()) >= cfg.k_updates:
                break

        # (3b) pack the top-k QUALIFIED updates as update blocks; if the
        # community could not produce k qualified updates (extreme malicious
        # fractions), the best qualified one fills the remaining slots so the
        # chain layout invariant holds (logged via duplicate uploader ids).
        records = sorted(
            consensus.accepted_records(), key=lambda r: -r.median_score
        )[: cfg.k_updates]
        if not records:  # nothing qualified: fall back to best available
            records = sorted(
                consensus.records, key=lambda r: -r.median_score
            )[:1]
        while len(records) < cfg.k_updates:
            records.append(records[0])
        packed_ids = [r.uploader for r in records]
        packed_scores = [r.median_score for r in records]
        packed_updates = [all_updates[u] for u in packed_ids]
        trainers = trainers_total
        weights = packed_scores if cfg.weight_by_score else None

        if cfg.quantize_chain:
            # quantized chain path: flatten the packed cohort once, quantize
            # the whole (K, D) stack in one kernel launch, store the int8
            # blobs as update blocks, and aggregate (4) STRAIGHT from the
            # quantized representation via the fused one-pass kernel — the
            # f32 stack never hits HBM.
            from repro.kernels.ops import aggregate_quantized, quantize_stack

            stack, unravel = flatten_updates(packed_updates)
            q, s, d = quantize_stack(stack)
            for i, (u, sc) in enumerate(zip(packed_ids, packed_scores)):
                self.chain.append_update(
                    {"q": q[i], "scales": s[i], "d": d}, u, sc, encoded=True
                )
                self.manager.nodes[u].score_history.append(sc)
            agg = unravel(aggregate_quantized(
                q, s, d, method=cfg.aggregation,
                weights=None if weights is None else jnp.asarray(weights),
                trim=cfg.trim,
            ))
        else:
            for i, (u, sc) in enumerate(zip(packed_ids, packed_scores)):
                self.chain.append_update(packed_updates[i], u, sc)
                self.manager.nodes[u].score_history.append(sc)

            # (4) aggregation trigger -> next model block
            agg = aggregate_pytrees(
                packed_updates, method=cfg.aggregation, weights=weights,
                trim=cfg.trim, use_kernels=cfg.use_kernels,
            )
        new_params = apply_update(params, agg)
        self.chain.append_model(new_params, t + 1)

        # (5) election + incentive + housekeeping
        cand = dict(zip(packed_ids, packed_scores))
        self.committee = election_mod.elect(
            cfg.election_method, rng, cand, self.q_committee
        ) or committee
        self._fill_committee()
        distribute_rewards(self.manager, cand, cfg.reward_pool)
        if cfg.kick_below >= 0:
            for r in consensus.records:
                if r.median_score < cfg.kick_below:
                    self.manager.kick(r.uploader)
        if cfg.prune_keep_rounds > 0:
            self.chain.prune(cfg.prune_keep_rounds)

        mal_nodes = {i for i, nd in self.manager.nodes.items() if nd.is_malicious}
        log = RoundLog(
            round=t,
            trainers=len(trainers),
            committee=len(committee),
            accepted_malicious=sum(
                1 for r in consensus.accepted_records() if r.uploader in mal_nodes
            ),
            packed_malicious=sum(1 for u in packed_ids if u in mal_nodes),
            mean_packed_score=float(np.mean(packed_scores)) if packed_scores else 0.0,
            consensus_validations=consensus.stats.validations,
            test_accuracy=self.evaluate() if eval_test else None,
        )
        self.logs.append(log)
        return log

    def run(self, rounds: int, eval_every: int = 5) -> List[RoundLog]:
        for r in range(rounds):
            self.run_round(eval_test=((r + 1) % eval_every == 0) or r == rounds - 1)
        return self.logs
