"""The BFLC round loop (paper Fig. 1): the decentralized runtime that ties
chain + committee consensus + election + incentive together.

Each round:
  (1) active nodes are sampled (k% participation; offline nodes never block),
  (2) trainers (active minus committee) locally train from the latest model
      block and submit updates to the committee,
  (3) the committee scores every update on its own local data (median over
      members), packs the top-k qualified updates as update blocks,
  (4) the smart-contract trigger fires at k updates: the committee aggregates
      them into the next model block,
  (5) a new committee is elected from this round's validated providers, and
      rewards are distributed by contribution.

Malicious behaviour (Gaussian-perturbation updates, collusive scoring) is
injected per §V.B when configured.

``BFLCRuntime`` is a thin facade: the five phases live in
``repro.fl.pipeline`` as pluggable stages (Sampler, LocalTrainer,
Validator, Packer, Aggregator, Elector, Rewarder), each swappable via a
string-keyed registry.  Pass ``stages={"aggregator": "my_impl"}`` (a
registered name or a bare callable) to swap any stage without touching
the pipeline; per-stage wall-clock timings land in ``stage_timings``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.core import election as election_mod
from repro.core.attacks import CollusionPolicy
from repro.core.blockchain import Chain
from repro.core.node import Node, NodeManager
from repro.data.synthetic import FederatedDataset
from repro.fl.adapter import ModelAdapter
from repro.fl.client import (
    make_eval_fn,
    make_local_train_fn,
    make_score_matrix_fn,
)
from repro.fl.pipeline import (
    RoundContext,
    build_pipeline,
    default_stage_names,
    fill_committee,
)


@dataclass
class BFLCConfig:
    active_proportion: float = 0.1
    committee_fraction: float = 0.4      # fraction of active nodes
    k_updates: int = 8                   # update blocks per round (chain k)
    local_steps: int = 20
    local_batch: int = 32
    local_lr: float = 0.02
    momentum: float = 0.9
    val_batch: int = 64
    election_method: str = election_mod.BY_SCORE
    accept_threshold: float = 0.5        # relative threshold (consensus stat)
    aggregation: str = "fedavg"
    trim: int = 1                        # trimmed_mean drop count per side
    weight_by_score: bool = True
    use_kernels: bool = False
    # store update blocks as int8 blobs (paper §IV.D) and aggregate straight
    # from the quantized chain representation via the fused Pallas pass —
    # one int8 read of the stack, no f32 (K, D) materialization.
    quantize_chain: bool = False
    # hierarchical rounds (paper §V scale-out, repro.fl.hier): tiers = S > 1
    # splits every round into S sub-communities, each running committee
    # consensus + aggregation on its own slice, with a second-level
    # committee round over the S sub-aggregates before the chain commit.
    # Peak update-stack memory is bounded by the largest slice, not O(P·D).
    # tiers = 1 is the flat pipeline, bit-identical to not setting it.
    tiers: int = 1
    malicious_fraction: float = 0.0
    attack: str = "gaussian"
    attack_sigma: float = 1.0
    collusion: bool = True
    kick_below: float = -1.0             # blacklist uploaders under this score
    # §IV.C's induction assumes the FIRST committee has an honest majority —
    # the managers' initial trusted set (§IV.A).  True = bootstrap round-0
    # committee from manager-vetted (non-malicious) nodes; False = uniform
    # random (the conspiracy scenario of Fig. 3).
    honest_bootstrap: bool = True
    prune_keep_rounds: int = 0           # >0: prune old payloads each round
    reward_pool: float = 10.0
    seed: int = 0


@dataclass
class RoundLog:
    round: int
    trainers: int
    committee: int
    accepted_malicious: int
    packed_malicious: int
    mean_packed_score: float
    consensus_validations: int
    test_accuracy: Optional[float] = None


class BFLCRuntime:
    def __init__(
        self,
        adapter: ModelAdapter,
        dataset: FederatedDataset,
        cfg: BFLCConfig,
        initial_params=None,
        stages: Optional[Dict[str, object]] = None,
        mesh=None,
        schedule: str = "sequential",
    ):
        if schedule not in ("sequential", "async"):
            raise ValueError(
                f"schedule={schedule!r} must be 'sequential' or 'async'"
            )
        if cfg.quantize_chain and not cfg.use_kernels:
            # the quantized chain path IS the fused Pallas engine; there is
            # no jnp fallback for it, so refuse the contradictory config
            # rather than silently overriding the use_kernels switch
            raise ValueError(
                "quantize_chain=True requires use_kernels=True "
                "(aggregation runs the fused Pallas int8 path)"
            )
        if cfg.tiers < 1:
            raise ValueError(f"tiers={cfg.tiers} must be >= 1")
        # a tiered round's final aggregation runs over S = tiers blocks,
        # a flat round's over k_updates — validate the trim against the
        # stack the aggregator will actually see
        agg_rows = cfg.tiers if cfg.tiers > 1 else cfg.k_updates
        if cfg.aggregation == "trimmed_mean" and not (
            0 <= 2 * cfg.trim < agg_rows
        ):
            # validate up front: by round time the update blocks are already
            # on the chain, and a failed aggregation would strand the round
            # mid-layout
            raise ValueError(
                f"trim={cfg.trim} invalid for {agg_rows} aggregated rows "
                f"(need 0 <= 2*trim < rows)"
            )
        self.adapter = adapter
        self.data = dataset
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)

        # node community: blacklist-mode manager, malicious ground truth
        self.manager = NodeManager()
        n = dataset.num_clients
        mal = set(
            self.rng.choice(
                n, int(round(cfg.malicious_fraction * n)), replace=False
            ).tolist()
        )
        for i in range(n):
            self.manager.join(
                Node(node_id=i, data_indices=np.arange(len(dataset.client_labels[i])),
                     is_malicious=i in mal)
            )

        # chain + genesis model block (#0: randomly initialized model, or a
        # warm start — new communities may bootstrap from an existing model)
        params = (initial_params if initial_params is not None
                  else adapter.init(jax.random.PRNGKey(cfg.seed)))
        self._codec = None
        if cfg.quantize_chain:
            from repro.kernels.ops import Int8UpdateCodec

            self._codec = Int8UpdateCodec(params)
        # tiered rounds store S sub-aggregate update blocks + one tier-2
        # committee block per round (repro.fl.hier / core.blockchain)
        tiered = cfg.tiers > 1
        self.chain = Chain(cfg.tiers if tiered else cfg.k_updates,
                           update_codec=self._codec, tier2_block=tiered)
        self.chain.append_model(params, 0)
        if self._codec is not None:
            self._dim = self._codec.dim
        else:
            from jax.flatten_util import ravel_pytree

            self._dim = int(ravel_pytree(params)[0].shape[0])

        # jitted batched helpers
        self._local_train = make_local_train_fn(adapter, cfg.local_lr, cfg.momentum)
        self._score_matrix = make_score_matrix_fn(adapter)
        self._eval = make_eval_fn(adapter)
        self._collusion = CollusionPolicy()

        # sharded round engine: one shard_mapped program set per mesh,
        # consumed by the local_sgd_sharded / *_sharded stages via ctx
        self.mesh = mesh
        self._sharded_train = None
        self._sharded_quantize = None
        self._sharded_agg = None
        self._sharded_score = None
        self._int8_score = None
        self._sharded_int8_score = None
        if mesh is not None:
            from repro.fl.client import (
                make_sharded_local_train_fn,
                make_sharded_score_matrix_fn,
            )
            from repro.kernels.ops import (
                make_aggregate_quantized_sharded,
                make_quantize_stack_sharded,
            )

            self._sharded_train = make_sharded_local_train_fn(
                adapter, cfg.local_lr, mesh, momentum=cfg.momentum
            )
            self._sharded_score = make_sharded_score_matrix_fn(adapter, mesh)
            if cfg.quantize_chain:
                self._sharded_quantize = make_quantize_stack_sharded(mesh)
                self._sharded_agg = make_aggregate_quantized_sharded(
                    mesh, method=cfg.aggregation, trim=cfg.trim
                )
        if cfg.quantize_chain:
            # fused score-from-int8 programs (opt-in committee_int8 /
            # committee_int8_sharded validators) share the chain codec's
            # unravel structure, so scored candidates decode exactly like
            # stored blobs
            from repro.fl.client import (
                make_score_from_int8_fn,
                make_sharded_score_from_int8_fn,
            )

            self._int8_score = make_score_from_int8_fn(
                adapter, self._codec.unravel
            )
            if mesh is not None:
                self._sharded_int8_score = make_sharded_score_from_int8_fn(
                    adapter, mesh, self._codec.unravel
                )

        # fixed per-round sizes: keeps XLA programs shape-stable (one compile).
        # Committee size >= 3: the median of two scores is their mean, so a
        # single colluding member controls it (observed takeover cascade in a
        # scaled-down Fig. 4 run with q=2 — the paper's own setting is q=18).
        n_active = max(2, int(round(n * cfg.active_proportion)))
        self.q_committee = max(3, int(round(n_active * cfg.committee_fraction)))
        self.p_trainers = max(cfg.k_updates, n_active - self.q_committee)

        # round-0 committee: no scores exist yet.  With honest_bootstrap the
        # managers seat their initial trusted nodes (the paper's §IV.C
        # precondition); otherwise uniform random — in which case a malicious
        # population q close to 1/2 can seat a colluding majority with the
        # Fig. 3 hypergeometric probability and take over permanently.
        active = self.manager.sample_active(self.rng, cfg.active_proportion)
        pool = active
        if cfg.honest_bootstrap:
            honest = [i for i in active
                      if not self.manager.nodes[i].is_malicious]
            pool = honest or active
        self.committee: List[int] = sorted(
            self.rng.choice(pool, min(self.q_committee, len(pool)),
                            replace=False).tolist()
        )
        self._fill_committee()
        self._hier_inner = None
        if tiered:
            from repro.fl.hier import build_hier_pipeline

            self.pipeline, self._hier_inner = build_hier_pipeline(
                cfg, mesh, stages
            )
        else:
            self.pipeline = build_pipeline(
                default_stage_names(cfg, mesh), stages
            )
        self.schedule = schedule
        if schedule == "async":
            # the async engine is a different *runner* over the same stage
            # set: bit-identical products (parity-gated), overlapped
            # execution (repro.fl.async_engine)
            from repro.fl.async_engine import AsyncRoundPipeline

            self.pipeline = AsyncRoundPipeline.from_pipeline(self.pipeline)
        self.logs: List[RoundLog] = []
        self.stage_timings: List[Dict[str, float]] = []
        # per-round hier memory accounting (tiers > 1): dicts with
        # peak_stack_bytes / flat_stack_bytes / tiers / max_slice_rows
        self.hier_logs: List[Dict[str, int]] = []

    def _fill_committee(self):
        """Keep committee size exactly q_committee (see pipeline.fill_committee)."""
        self.committee = fill_committee(
            self.manager, self.committee, self.q_committee
        )

    # ------------------------------------------------------------------
    def global_params(self):
        return self.chain.latest_model()[1]

    def evaluate(self) -> float:
        return self._eval(self.global_params(), self.data.test_images,
                          self.data.test_labels)

    # ------------------------------------------------------------------
    def run_round(self, eval_test: bool = False) -> RoundLog:
        t, params = self.chain.latest_model()
        committee = [i for i in self.committee if i in self.manager.nodes]
        ctx = RoundContext(
            cfg=self.cfg,
            rng=self.rng,
            adapter=self.adapter,
            data=self.data,
            params=params,
            round=t,
            manager=self.manager,
            chain=self.chain,
            round_committee=committee,
            committee=list(committee),
            q_committee=self.q_committee,
            p_trainers=self.p_trainers,
            local_train_fn=self._local_train,
            score_matrix_fn=self._score_matrix,
            collusion=self._collusion,
            mesh=self.mesh,
            sharded_train_fn=self._sharded_train,
            sharded_quantize_fn=self._sharded_quantize,
            sharded_agg_fn=self._sharded_agg,
            sharded_score_fn=self._sharded_score,
            int8_score_fn=self._int8_score,
            sharded_int8_score_fn=self._sharded_int8_score,
        )
        if self.cfg.tiers > 1:
            from repro.fl.hier import HierState

            ctx.hier = HierState(tiers=self.cfg.tiers,
                                 inner_validator=self._hier_inner,
                                 dim=self._dim)
        self.pipeline.run(ctx)
        self.committee = ctx.committee
        if ctx.hier is not None:
            self.hier_logs.append({
                "tiers": ctx.hier.tiers,
                "peak_stack_bytes": ctx.hier.peak_stack_bytes,
                "flat_stack_bytes": ctx.hier.flat_stack_bytes,
                "max_slice_rows": ctx.hier.max_slice_rows,
                "t1_validations": ctx.hier.t1_validations,
            })

        mal_nodes = {i for i, nd in self.manager.nodes.items() if nd.is_malicious}
        log = RoundLog(
            round=t,
            trainers=len(ctx.trainers_total),
            committee=len(committee),
            accepted_malicious=sum(
                1 for r in ctx.consensus.accepted_records()
                if r.uploader in mal_nodes
            ) if ctx.consensus is not None else 0,
            packed_malicious=sum(1 for u in ctx.packed_ids if u in mal_nodes),
            mean_packed_score=(float(np.mean(ctx.packed_scores))
                               if ctx.packed_scores else 0.0),
            consensus_validations=(ctx.consensus.stats.validations
                                   if ctx.consensus is not None else 0),
            test_accuracy=self.evaluate() if eval_test else None,
        )
        self.logs.append(log)
        self.stage_timings.append(dict(ctx.timings))
        return log

    def run(self, rounds: int, eval_every: int = 5) -> List[RoundLog]:
        for r in range(rounds):
            self.run_round(eval_test=((r + 1) % eval_every == 0) or r == rounds - 1)
        return self.logs
