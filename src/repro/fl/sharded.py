"""The sharded round engine: multi-device BFLC stages (ROADMAP follow-ups).

Three registered stages turn one round into a data-parallel program over a
1-D ``("data",)`` mesh (``repro.launch.mesh.make_round_mesh``), with zero
edits to the round loop:

* ``local_trainer = "local_sgd_sharded"`` — the P-client vmapped local-SGD
  program (``repro.fl.client``) shard_mapped over the mesh's data axis: P
  clients split across devices, each device scanning its client shard, the
  stacked update pytree all-gathered when the host unstacks it.  Batch
  sampling and attack injection are byte-identical to ``local_sgd`` (shared
  helpers), so a fixed seed yields the same rng stream — and the per-client
  math is the same XLA program, so f32 chain hashes match the single-device
  engine bit-for-bit.
* ``packer = "top_k_int8_sharded"`` — sharding-aware ``Packer``: the int8
  stack is built per-shard (each device quantizes its D-slice; tiles are
  BLOCK_D-aligned by construction so per-tile scales coincide with the
  single-device codec), blobs land on the chain in the same
  ``{"q", "scales", "d"}`` schema.
* ``aggregator = "fused_int8_sharded"`` — each device runs the fused
  int8->dequant->reduce kernel (PR 1) on its D-shard of the stack, then the
  model block is all-gathered (XLA inserts it at the first replicated use).
* ``validator = "committee_sharded"`` — the P x Q committee score matrix
  (paper §III.B, the consensus-side cost term of §V.A) shard_mapped over
  the mesh's data axis: each device scores its own P-shard of candidate
  rows against the replicated params + member val batches.  Updates arrive
  P-sharded straight from ``local_sgd_sharded`` (no intermediate
  all-gather when no row was poisoned); only the (P, Q) score matrix is
  gathered at the stage boundary, per the trainer's
  boundary-materialization rule.  Scores are bitwise identical to the
  single-device oracle — same per-candidate XLA program, just sharded.
* ``validator = "committee_int8_sharded"`` (opt-in) — same sharding, but
  each device flattens its P-shard of the trainer's device-resident update
  stack in-program, quantizes the rows with the chain codec and rebuilds
  candidates via the fused score-from-int8 Pallas pass
  (``repro.kernels.fused_score``): the committee scores exactly the blob a
  quantizing packer would store, within int8 tolerance of the f32 scores.
  The per-row (q, scales) are cached on the context so the packer reuses
  them instead of re-quantizing.

The stages read their pre-built programs from ``RoundContext``
(``sharded_train_fn`` / ``sharded_quantize_fn`` / ``sharded_agg_fn`` /
``sharded_score_fn`` / ``sharded_int8_score_fn``, built once per runtime
by ``BFLCRuntime(..., mesh=...)`` — see ``repro.api.build_runtime``).
Everything runs on CPU under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``, which is how the
differential test harness (tests/test_sharded_round.py) exercises 1/2/8
devices without a TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import flatten_updates, normalize_weights
from repro.fl.pipeline import (
    CommitteeValidator,
    LocalSGDTrainer,
    RoundContext,
    _select_top_k,
    _set_packed,
    _commit_aggregate,
    _stack,
    _unstack,
    cache_row_quant,
    cached_row_stack,
    poison_cohort_updates,
    register,
    sample_cohort_batches,
)


def _require(ctx: RoundContext, field: str, stage: str):
    fn = getattr(ctx, field)
    if fn is None:
        raise RuntimeError(
            f"{stage} needs ctx.{field} — build the runtime with a mesh "
            "(build_runtime(..., mesh=make_round_mesh(n)))"
        )
    return fn


def _pad_rows(tree, n: int, ndev: int):
    """Pad the leading (client) axis of a stacked pytree / array to a
    multiple of the mesh's data-axis size by repeating the last row.
    Per-row programs (local SGD, committee scoring) are independent, so
    padded rows never contaminate real clients and score rows are simply
    sliced off."""
    pad = (-n) % ndev
    if pad == 0:
        return tree
    return jax.tree.map(
        lambda x: np.concatenate([x, np.repeat(x[-1:], pad, axis=0)])
        if isinstance(x, np.ndarray)
        else jnp.concatenate([x, jnp.repeat(x[-1:], pad, axis=0)]),
        tree,
    )


def _pad_clients(xs: np.ndarray, ys: np.ndarray, ndev: int):
    """The trainer's batch padding: one `_pad_rows` over the (xs, ys) pair."""
    P = xs.shape[0]
    xs, ys = _pad_rows((xs, ys), P, ndev)
    return xs, ys, P


class ShardedLocalSGDTrainer(LocalSGDTrainer):
    """(2, sharded) cohort-batched local SGD, clients split over the mesh's
    data axis; one shard_mapped XLA program per cohort shape.  Same
    dispatch/finalize split as ``LocalSGDTrainer``: ``dispatch`` draws the
    batches and launches the shard_mapped program (result in flight on
    ``ctx.train_inflight``); ``finalize`` pays the host transfer and
    injects attacks."""

    def dispatch(self, ctx: RoundContext) -> None:
        train_fn = _require(ctx, "sharded_train_fn", "local_sgd_sharded")
        mesh = _require(ctx, "mesh", "local_sgd_sharded")
        ndev = dict(mesh.shape).get("data", mesh.devices.size)
        xs, ys = sample_cohort_batches(ctx)
        xs, ys, _ = _pad_clients(xs, ys, ndev)
        stacked = train_fn(ctx.params, xs, ys)
        # the P-sharded update stack (padded rows included) stays on its
        # devices for the sharded validator — committee scoring consumes it
        # with zero relayout.
        ctx.cohort_stacked = stacked
        ctx.train_inflight = stacked

    def finalize(self, ctx: RoundContext) -> None:
        # the host copy is still needed: poisoning, per-uploader
        # bookkeeping (ctx.updates) and packing are host-side, and feeding
        # the later single-device stages a device-committed P-sharded
        # stack would make GSPMD replicate their compute per shard
        # (observed: pack/aggregate re-sharding pathology before this
        # gather).
        host = jax.device_get(ctx.train_inflight)
        ctx.train_inflight = None
        updates = _unstack(host, len(ctx.trainers))  # padded rows dropped
        poison_cohort_updates(ctx, updates)
        ctx.cohort_updates = updates


train_local_sgd_sharded = register("local_trainer", "local_sgd_sharded")(
    ShardedLocalSGDTrainer()
)


def _pad_cached_to_shards(q, s, d: int, ndev: int):
    """Widen cached rows from the single-device width ``padded_dim(d)`` to
    the sharded width ``padded_dim_sharded(d, ndev)``.  The extra tiles
    are all-zero and the quantize kernel maps an all-zero tile to q=0 /
    scale=1.0, so appending exactly that is bitwise identical to
    quantizing the wider stack."""
    from repro.kernels.ops import padded_dim_sharded
    from repro.kernels.tiling import BLOCK_D

    pad = padded_dim_sharded(d, ndev) - q.shape[1]
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad)))
        s = jnp.pad(s, ((0, 0), (0, pad // BLOCK_D)),
                    constant_values=1.0)
    return q, s


@register("packer", "top_k_int8_sharded")
def pack_top_k_int8_sharded(ctx: RoundContext) -> None:
    """Sharding-aware quantized packing: flatten the packed cohort once,
    quantize each device's D-shard of the (K, D) stack in parallel, store
    int8 blobs as update blocks, hand the (sharded) int8 stack to the
    sharded aggregator.  Rows already quantized by an int8 validator are
    reused from the row-quant cache (zero-padded to the shard boundary)
    instead of re-quantized."""
    quantize_fn = _require(ctx, "sharded_quantize_fn", "top_k_int8_sharded")
    mesh = _require(ctx, "mesh", "top_k_int8_sharded")
    ndev = dict(mesh.shape).get("data", mesh.devices.size)
    _set_packed(ctx, _select_top_k(ctx))
    cached = cached_row_stack(ctx)
    if cached is not None:
        q, s, d = cached
        q, s = _pad_cached_to_shards(q, s, d, ndev)
        unravel = ctx.chain.codec.unravel
    else:
        stack, unravel = flatten_updates(ctx.packed_updates)
        d = stack.shape[1]
        q, s = quantize_fn(stack)
    # one gather for the whole stack: slicing rows of the D-sharded arrays
    # inside the loop would pay a cross-device gather + host transfer per
    # blob (the digest reads the bytes anyway); the aggregator still gets
    # the sharded (q, s) below
    qh, sh = jax.device_get((q, s))
    for i, (u, sc) in enumerate(zip(ctx.packed_ids, ctx.packed_scores)):
        ctx.chain.append_update(
            {"q": qh[i], "scales": sh[i], "d": d}, u, sc, encoded=True
        )
        ctx.manager.nodes[u].score_history.append(sc)
    ctx.packed_quantized = (q, s, d, unravel)


class ShardedCommitteeValidator(CommitteeValidator):
    """(3, sharded) the P x Q committee score matrix shard_mapped over the
    mesh's data axis — each device scores its P-shard of candidates; only
    the (P, Q) matrix is gathered at the stage boundary.  Consensus
    bookkeeping (collusion overlay, median acceptance, trigger) is
    inherited unchanged from ``CommitteeValidator``."""

    def _scores_device(self, ctx: RoundContext):
        score_fn = _require(ctx, "sharded_score_fn", "committee_sharded")
        mesh = _require(ctx, "mesh", "committee_sharded")
        ndev = dict(mesh.shape).get("data", mesh.devices.size)
        n = len(ctx.cohort_updates)
        if ctx.cohort_stacked is not None and not ctx.cohort_poisoned:
            # the trainer's update stack is still bit-identical to the
            # host-side update list AND already P-sharded on this mesh:
            # score it in place — no host round-trip, no relayout
            stacked = ctx.cohort_stacked
        else:
            stacked = _pad_rows(_stack(ctx.cohort_updates), n, ndev)
        return score_fn(ctx.params, stacked, ctx.val_x, ctx.val_y)


register("validator", "committee_sharded")(ShardedCommitteeValidator())


class Int8ShardedCommitteeValidator(CommitteeValidator):
    """(3, sharded, opt-in) fused score-from-int8: each device quantizes
    its P-shard of update rows with the chain codec and rebuilds the
    candidates in one fused Pallas read (dequantize in-register, delta
    applied during the base-parameter load) — the committee scores exactly
    the blob a quantizing packer would store, and the f32 (P, D) stack is
    materialized once, never twice."""

    def _scores_device(self, ctx: RoundContext):
        score_fn = _require(
            ctx, "sharded_int8_score_fn", "committee_int8_sharded"
        )
        mesh = _require(ctx, "mesh", "committee_int8_sharded")
        ndev = dict(mesh.shape).get("data", mesh.devices.size)
        n = len(ctx.cohort_updates)
        if ctx.cohort_stacked is not None and not ctx.cohort_poisoned:
            # the trainer's device-resident stack is still bit-identical
            # to the host-side update list AND already P-sharded on this
            # mesh: the scorer flattens it in-program — no host flatten,
            # no relayout
            stacked = ctx.cohort_stacked
        else:
            stacked = _pad_rows(_stack(ctx.cohort_updates), n, ndev)
        scores, q, s = score_fn(
            ctx.params, stacked, ctx.val_x, ctx.val_y
        )
        d = int(sum(np.prod(l.shape[1:])
                    for l in jax.tree.leaves(stacked)))
        cache_row_quant(ctx, q, s, d)
        return scores


register("validator", "committee_int8_sharded")(Int8ShardedCommitteeValidator())


@register("aggregator", "fused_int8_sharded")
def aggregate_fused_int8_sharded(ctx: RoundContext) -> None:
    """(4, sharded) fused one-pass aggregation of each device's D-shard of
    the chain's int8 representation; the reduced model block is
    all-gathered into the replicated params."""
    agg_fn = _require(ctx, "sharded_agg_fn", "fused_int8_sharded")
    if ctx.packed_quantized is None:
        raise RuntimeError(
            "fused_int8_sharded aggregator needs a quantizing packer (e.g. "
            "'top_k_int8_sharded') to stage the int8 stack in "
            "ctx.packed_quantized"
        )
    q, s, d, unravel = ctx.packed_quantized
    w = normalize_weights(q.shape[0], None if ctx.weights is None
                          else jax.numpy.asarray(ctx.weights))
    # materialize the all-gather once: the reduced vector becomes the next
    # model block, and every next-round stage (local training dispatch,
    # P x Q scoring) is keyed on replicated params — leaving them
    # D-sharded re-shards each of those programs instead (same pathology
    # as the trainer's gather above)
    flat = np.asarray(agg_fn(q, s, w)[:d])
    _commit_aggregate(ctx, unravel(flat))
