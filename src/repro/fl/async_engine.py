"""Asynchronous pipelined round engine (ROADMAP item 1).

The sequential driver (``repro.fl.pipeline.RoundPipeline``) runs
sample -> train -> validate strictly in cohort order and blocks on every
stage's device work before starting the next (``_timed``'s blanket sync).
Train is ~90% of a sharded round, and everything the committee does after
a cohort trains — gathering the score matrix, consensus bookkeeping,
sub-aggregation, chain hashing — is host-side work during which the mesh
sits idle.  This module replaces the *schedule*, not the stages: the same
registered Sampler/LocalTrainer/Validator/... stage set is executed as a
dependency graph whose nodes are the stages' dispatch/finalize halves, so
cohort t+1's local-SGD program is already in flight on the mesh while the
host finishes cohort t's committee work.

Design
------
* **Cohort ring.**  Per-cohort context fields (``SLOT_FIELDS``) live in
  ``CohortSlot``s and are staged slot <-> ctx around every node, so two
  cohorts can be in flight without clobbering each other.  The ring is
  two deep: starting cohort t+1 requires cohort t-1 fully finalized
  (edge ``sample[t+1] <- validate_finalize[t-1]``), bounding in-flight
  update stacks to two — a tiered round keeps its streaming-ingest
  memory bound at two slices instead of one.
* **Dependency graph.**  Each cohort contributes sample ->
  train_dispatch -> train_finalize -> validate_dispatch ->
  validate_finalize nodes (stages without a dispatch/finalize split run
  as one atomic node — a serialization point, never an error).
  validate_dispatch t reads trainer t's ``cohort_stacked``; validator
  nodes are serialized across cohorts (the consensus trigger and the
  sampler's ``i not in ctx.updates`` exclusion read their products); the
  tail pack -> aggregate -> elect -> reward runs once after the last
  finalize, so **chain append is ordered** exactly as in the sequential
  engine.  (The elector -> next round's committee exclusion edge is the
  runtime's round loop boundary — rounds never overlap, since round t+1
  trains from round t's model block.)
* **rng edges.**  Bit-identical parity with the sequential engine
  requires the host ``np.random.Generator`` stream to be consumed in the
  sequential order.  Every node that may draw host rng (sampling, batch
  draws, attack injection when the cohort holds malicious trainers,
  collusion overlay when the scoring committee holds malicious members,
  a hier slice's inner prepare) is chained along "rng edges" in creation
  order = sequential order.  With no malicious nodes the chain is
  sample -> train_dispatch -> validate_dispatch -> ... which still
  permits full train/validate overlap; with malicious nodes the chain
  runs through the finalize nodes and the graph degrades to the
  sequential order — which is exactly when the parity tests demand
  bit-identical chain hashes, and they get them in both regimes.
* **Sampler prefetch.**  A sampler advertising ``prefetch_safe = True``
  (the tiered sampler: partition frozen at cohort 0) lets cohort t+1 be
  sampled + train-dispatched while cohort t is still validating — the
  headline overlap (hier slice s+1 trains while slice s sub-aggregates).
  The flat samplers read the validator's admissions, so flat
  multi-cohort rounds serialize sample[t+1] behind validate_finalize[t]
  — the engine never speculates an rng draw it might have to undo.
* **Sync points.**  There is no blanket ``block_until_ready``: device
  work is awaited where a stage half genuinely consumes it
  (``train_finalize``'s host gather, ``validate_finalize``'s score
  gather, the tail's chain digests) plus one final sync in the reward
  node.  Per-node host time is accumulated into ``ctx.timings`` under
  the same ``STAGE_TIMING_KEYS`` buckets as the sequential engine
  (dispatch time + whatever blocking its own sync point pays), so
  BENCH_round rows keep their schema; buckets are host-attributed —
  overlapped device time lands in whichever bucket blocked on it.
* **Failure.**  A node that raises aborts the run immediately: no tail
  node has run, so nothing was appended to the chain — a mid-ring
  failure cannot tear the chain layout (gated in tests), and in-flight
  device work for the next cohort is simply abandoned.

``BFLCRuntime``/``FLTrainer`` select this engine via
``build_runtime(..., schedule="async")``; ``AsyncRoundPipeline.run``
consumes and returns the same ``RoundContext`` and is bit-identical to
``RoundPipeline.run`` for every stage set shipped in this repo (parity
suite: tests/test_async_round.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.fl.pipeline import (
    RoundContext,
    RoundPipeline,
    STAGE_TIMING_KEYS,
    _sync_tree,
)

# per-cohort RoundContext fields staged between ring slots and the shared
# context around every node
SLOT_FIELDS = (
    "cohort", "trainers", "cohort_updates", "cohort_stacked",
    "cohort_poisoned", "cohort_scores", "train_inflight", "row_quant",
)

RING_DEPTH = 2


@dataclass
class CohortSlot:
    """One ring slot: the per-cohort slice of RoundContext."""

    cohort: int
    trainers: List[int] = field(default_factory=list)
    cohort_updates: List[Any] = field(default_factory=list)
    cohort_stacked: Any = None
    cohort_poisoned: List[int] = field(default_factory=list)
    cohort_scores: Any = None
    train_inflight: Any = None
    row_quant: Dict[int, Any] = field(default_factory=dict)


@dataclass
class StageNode:
    """One schedulable unit: a stage (or stage half) bound to a cohort."""

    key: str                               # e.g. "train_dispatch[2]"
    kind: str                              # scheduler event class
    bucket: str                            # STAGE_TIMING_KEYS entry
    fn: Callable[[RoundContext], None]
    deps: List["StageNode"] = field(default_factory=list)
    slot: Optional[CohortSlot] = None
    cohort: Optional[int] = None
    rng: bool = False                      # consumes host rng
    priority: int = 1                      # 0 = dispatch-class (run first)
    order: int = 0                         # creation = sequential order
    done: bool = False
    skipped: bool = False


@dataclass
class AsyncRoundPipeline:
    """Drop-in replacement for ``RoundPipeline`` running the async
    schedule.  Same stage fields; ``run(ctx)`` mutates and returns the
    same ``RoundContext``."""

    sampler: Any
    local_trainer: Any
    validator: Any
    packer: Any
    aggregator: Any
    elector: Any
    rewarder: Any
    max_cohorts: int = 3

    @classmethod
    def from_pipeline(cls, p: RoundPipeline) -> "AsyncRoundPipeline":
        return cls(p.sampler, p.local_trainer, p.validator, p.packer,
                   p.aggregator, p.elector, p.rewarder, p.max_cohorts)

    def run(self, ctx: RoundContext) -> RoundContext:
        _AsyncRoundRun(self, ctx).run()
        return ctx


def _split(stage) -> bool:
    return hasattr(stage, "dispatch") and hasattr(stage, "finalize")


class _AsyncRoundRun:
    """One round's node graph + executor (grown cohort-by-cohort: a
    cohort's trainer/validator nodes and rng hazards depend on the
    sampled trainer list, so they are created when its sample runs)."""

    def __init__(self, pipe: AsyncRoundPipeline, ctx: RoundContext):
        self.pipe = pipe
        self.ctx = ctx
        self.nodes: List[StageNode] = []
        self.slots: Dict[int, CohortSlot] = {}
        self._order = 0
        self._rng_tail: Optional[StageNode] = None   # last rng-consuming node
        self._last_v: Optional[StageNode] = None     # validator serialization
        self._vf: Dict[int, StageNode] = {}          # cohort -> final V node
        self._samples: Dict[int, StageNode] = {}
        self._tail_made = False

    # ------------------------------------------------------------------
    # graph construction
    # ------------------------------------------------------------------
    def _add(self, key: str, kind: str, bucket: str, fn, *, deps=(),
             slot=None, cohort=None, rng=False, priority=1) -> StageNode:
        node = StageNode(key=key, kind=kind, bucket=bucket, fn=fn,
                         deps=[d for d in deps if d is not None],
                         slot=slot, cohort=cohort, rng=rng,
                         priority=priority, order=self._order)
        self._order += 1
        if rng:
            # chain host-rng consumers in creation (= sequential) order so
            # a fixed seed replays the sequential engine's exact stream
            if self._rng_tail is not None and self._rng_tail is not node:
                node.deps.append(self._rng_tail)
            self._rng_tail = node
        self.nodes.append(node)
        return node

    def _cohort_committee(self, c: int) -> List[int]:
        """The committee whose members score cohort c (collusion-rng
        hazard set): the slice sub-committee in a tiered round, the round
        committee otherwise."""
        hier = self.ctx.hier
        if hier is not None and hier.slices:
            return (hier.slices[c].committee
                    if c < len(hier.slices) else [])
        return self.ctx.round_committee

    def _add_sample(self, c: int) -> StageNode:
        sampler = self.pipe.sampler
        prefetch = bool(getattr(sampler, "prefetch_safe", False))
        rng = True
        if c > 0 and getattr(sampler, "rng_first_only", False):
            rng = False
        deps = []
        if c == 0:
            deps = [self._last_v]          # prepare node, when present
        elif prefetch:
            deps = [self._samples[c - 1], self._vf.get(c - RING_DEPTH)]
        else:
            # flat samplers read the validator's admissions (collected
            # trigger, `i not in ctx.updates` exclusion): no speculation
            deps = [self._vf[c - 1]]
        slot = CohortSlot(cohort=c)
        self.slots[c] = slot
        node = self._add(f"sample[{c}]", "sample", "sample",
                         self.pipe.sampler, deps=deps, slot=slot,
                         cohort=c, rng=rng, priority=0)
        self._samples[c] = node
        return node

    def _add_cohort_body(self, c: int) -> None:
        """Trainer + validator nodes for a sampled, non-empty cohort."""
        ctx, pipe = self.ctx, self.pipe
        slot = self.slots[c]
        cfg = ctx.cfg
        snode = self._samples[c]
        poisoned = any(ctx.is_malicious(i) for i in slot.trainers)
        collusion = bool(getattr(cfg, "collusion", False)) and any(
            ctx.is_malicious(m) for m in self._cohort_committee(c)
        )

        trainer, validator = pipe.local_trainer, pipe.validator
        if _split(trainer):
            td = self._add(f"train_dispatch[{c}]", "train", "train",
                           trainer.dispatch, deps=[snode], slot=slot,
                           cohort=c, rng=True, priority=0)
            tf = self._add(f"train_finalize[{c}]", "train", "train",
                           trainer.finalize, deps=[td], slot=slot,
                           cohort=c, rng=poisoned)
        else:
            tf = self._add(f"train[{c}]", "train", "train", trainer,
                           deps=[snode], slot=slot, cohort=c, rng=True)

        if _split(validator):
            vd = self._add(f"validate_dispatch[{c}]", "validate",
                           "validate", validator.dispatch,
                           deps=[tf, self._last_v], slot=slot, cohort=c,
                           rng=bool(getattr(validator, "dispatch_uses_rng",
                                            False)),
                           priority=0)
            vf = self._add(f"validate_finalize[{c}]", "validate_finalize",
                           "validate", validator.finalize, deps=[vd],
                           slot=slot, cohort=c, rng=collusion)
        else:
            # unknown monolithic validator: conservatively an rng consumer
            vf = self._add(f"validate[{c}]", "validate_finalize",
                           "validate", validator,
                           deps=[tf, self._last_v], slot=slot, cohort=c,
                           rng=True)
        self._vf[c] = vf
        self._last_v = vf

        if c + 1 < pipe.max_cohorts:
            self._add_sample(c + 1)

    def _make_tail(self, trigger: StageNode, slot: CohortSlot) -> None:
        """pack -> aggregate -> elect -> reward, serialized after the last
        cohort node — all chain appends happen here, in order."""
        if self._tail_made:
            return
        self._tail_made = True
        pipe = self.pipe
        dep = [trigger, self._last_v]

        def _reward_and_sync(ctx: RoundContext) -> None:
            pipe.rewarder(ctx)
            # the round's final sync point: nothing a caller observes
            # (new params, chain, logs) may still be in flight
            jax.block_until_ready(_sync_tree(ctx))

        for key, fn in (("pack", pipe.packer),
                        ("aggregate", pipe.aggregator),
                        ("elect", pipe.elector),
                        ("reward", _reward_and_sync)):
            node = self._add(key, "tail", key, fn, deps=dep, slot=slot,
                             rng=True)
            dep = [node]

    # ------------------------------------------------------------------
    # scheduler events
    # ------------------------------------------------------------------
    def _after_sample(self, node: StageNode) -> None:
        if self._tail_made:
            return
        if not node.slot.trainers:
            # empty cohort = the sequential loop's break
            self._make_tail(node, node.slot)
            return
        self._add_cohort_body(node.cohort)

    def _after_validate(self, node: StageNode) -> None:
        if self._tail_made:
            return
        c = node.cohort
        ctx = self.ctx
        if ctx.collected:
            nxt = self._samples.get(c + 1)
            if nxt is not None and not nxt.done:
                nxt.skipped = True
            live = [n for n in self.nodes
                    if n.cohort is not None and n.cohort > c
                    and (n.done or n.kind != "sample") and not n.skipped]
            if live:
                # a prefetch_safe sampler promised `collected` fires only
                # on the last cohort; it fired early with cohort c+1 work
                # (and its rng draws) already issued — refuse to continue
                # with a stream the sequential engine would not have drawn
                raise RuntimeError(
                    "async schedule: `collected` fired at cohort "
                    f"{c} with cohort {c + 1} already prefetched — the "
                    "sampler's prefetch_safe contract requires the "
                    "trigger to be shape-static (last cohort only)"
                )
            self._make_tail(node, node.slot)
        elif c + 1 >= self.pipe.max_cohorts:
            self._make_tail(node, node.slot)   # max_cohorts exhausted

    # ------------------------------------------------------------------
    # executor
    # ------------------------------------------------------------------
    def _pick(self) -> Optional[StageNode]:
        best = None
        best_k = None
        for n in self.nodes:
            if n.done or n.skipped:
                continue
            # a skipped dep (a cancelled prefetch sample) counts as
            # satisfied: it never ran, never will, and everything *it*
            # waited on was already done when it was skipped — its rng
            # successors (the tail) are free to proceed
            if any(not (d.done or d.skipped) for d in n.deps):
                continue
            k = (n.priority, n.order)
            if best is None or k < best_k:
                best, best_k = n, k
        return best

    def _exec(self, node: StageNode) -> None:
        ctx = self.ctx
        t0 = time.perf_counter()
        slot = node.slot
        if slot is not None:
            for f in SLOT_FIELDS:
                setattr(ctx, f, getattr(slot, f))
        try:
            node.fn(ctx)
        finally:
            if slot is not None:
                for f in SLOT_FIELDS:
                    setattr(slot, f, getattr(ctx, f))
        node.done = True
        ctx.timings[node.bucket] = (
            ctx.timings.get(node.bucket, 0.0) + (time.perf_counter() - t0)
        )
        if node.kind == "sample":
            self._after_sample(node)
        elif node.kind == "validate_finalize":
            self._after_validate(node)

    def run(self) -> None:
        ctx, pipe = self.ctx, self.pipe
        for key in STAGE_TIMING_KEYS:
            ctx.timings.setdefault(key, 0.0)
        prepare = getattr(pipe.validator, "prepare", None)
        if prepare is not None:
            self._last_v = self._add("prepare", "prepare", "validate",
                                     prepare, rng=True)
        if pipe.max_cohorts < 1:
            self._make_tail(self._last_v, CohortSlot(cohort=0))
        else:
            self._add_sample(0)
        while True:
            node = self._pick()
            if node is None:
                break
            self._exec(node)
        stuck = [n.key for n in self.nodes if not n.done and not n.skipped]
        if stuck:
            raise RuntimeError(
                f"async schedule deadlock: unrunnable nodes {stuck}"
            )
