"""ModelAdapter: the minimal interface BFLC needs from a global model.

The chain stores pytrees; the committee needs loss/accuracy.  Everything
else (CNN for the paper's experiments, the 10-arch LM zoo for the
production path) plugs in through this.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class ModelAdapter(NamedTuple):
    init: Callable[[Any], Any]                     # key -> params
    loss: Callable[[Any, Any, Any], jnp.ndarray]   # (params, x, y) -> scalar
    accuracy: Callable[[Any, Any, Any], jnp.ndarray]


def femnist_adapter(width: int = 32) -> ModelAdapter:
    from repro.configs import femnist_cnn as cnn

    return ModelAdapter(
        init=lambda key: cnn.init_params(key, width=width),
        loss=cnn.loss_fn,
        accuracy=lambda p, x, y: cnn.accuracy(p, x, y),
    )


def lm_adapter(cfg) -> ModelAdapter:
    """Language-model adapter: batch = (tokens, targets+mask packed)."""
    from repro.models import forward
    from repro.models.transformer import Batch

    def loss(params, tokens, targets):
        b = Batch(
            tokens=tokens,
            positions=jnp.broadcast_to(
                jnp.arange(tokens.shape[1], dtype=jnp.int32)[None], tokens.shape
            ),
            targets=targets,
        )
        logits, aux = forward(params, cfg, b)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        return nll.mean() + aux

    def accuracy(params, tokens, targets):
        b = Batch(
            tokens=tokens,
            positions=jnp.broadcast_to(
                jnp.arange(tokens.shape[1], dtype=jnp.int32)[None], tokens.shape
            ),
            targets=targets,
        )
        logits, _ = forward(params, cfg, b)
        return (logits.argmax(-1) == targets).mean()

    from repro.models import init_model

    return ModelAdapter(
        init=lambda key: init_model(key, cfg),
        loss=loss,
        accuracy=accuracy,
    )
