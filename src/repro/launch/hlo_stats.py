"""Extract roofline terms from compiled XLA artifacts.

``cost_analysis`` gives HLO FLOPs and bytes accessed; collective bytes are
NOT in cost_analysis, so we parse the post-SPMD optimized HLO text and sum
output-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op.

Collectives inside ``while`` loops (the layer-unit scan!) execute
trip-count-many times but appear once in the text, so the parser walks the
computation call graph and multiplies by XLA's ``known_trip_count``.

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

PEAK_FLOPS = 197e12         # bf16 per chip
HBM_BW = 819e9              # bytes/s per chip
ICI_BW = 50e9               # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|([\w\[\],\{\}]+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)
_WHILE_RE = re.compile(r"while\(.*?body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"\scall\(.*?to_apply=%?([\w\.\-]+)")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def add(self, kind: str, nbytes: int, mult: int = 1):
        self.bytes_by_kind[kind] = self.bytes_by_kind.get(kind, 0) + nbytes * mult
        self.count_by_kind[kind] = self.count_by_kind.get(kind, 0) + mult


def _split_computations(hlo_text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    entry = None
    for line in hlo_text.splitlines():
        m = _COMP_HDR.match(line.strip())
        if m and ("->" in line):
            cur = m.group(1)
            comps[cur] = []
            if line.strip().startswith("ENTRY"):
                entry = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


def collective_stats(hlo_text: str) -> CollectiveStats:
    comps = _split_computations(hlo_text)
    entry_lines = comps.get("__entry__")
    if entry_lines is None:  # fallback: flat scan, no loop multipliers
        stats = CollectiveStats()
        for m in _COLL_RE.finditer(hlo_text):
            tshapes, sshape, kind, suffix = m.groups()
            if suffix == "-done":
                continue
            stats.add(kind, _shape_bytes(tshapes or sshape or ""))
        return stats

    stats = CollectiveStats()
    seen_stack: List[str] = []

    def visit(lines: List[str], mult: int):
        for line in lines:
            cm = _COLL_RE.search(line)
            if cm:
                tshapes, sshape, kind, suffix = cm.groups()
                if suffix != "-done":
                    stats.add(kind, _shape_bytes(tshapes or sshape or ""), mult)
                continue
            wm = _WHILE_RE.search(line)
            if wm:
                body = wm.group(1)
                tm = _TRIP_RE.search(line)
                trips = int(tm.group(1)) if tm else 1
                if body in comps and body not in seen_stack:
                    seen_stack.append(body)
                    visit(comps[body], mult * trips)
                    seen_stack.pop()
                continue
            km = _CALL_RE.search(line)
            if km and km.group(1) in comps and km.group(1) not in seen_stack:
                seen_stack.append(km.group(1))
                visit(comps[km.group(1)], mult)
                seen_stack.pop()

    visit(entry_lines, 1)
    return stats


_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\w+)\[([\d,]*)\]"
)
_DOT_LINE_RE = re.compile(
    r"\sdot\(([^)]*)\).*?lhs_contracting_dims=\{([\d,]*)\}"
)
_CONV_LINE_RE = re.compile(r"\sconvolution\(([^)]*)\)")
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")


def _symbol_table(lines: List[str]) -> Dict[str, Tuple[str, List[int]]]:
    table: Dict[str, Tuple[str, List[int]]] = {}
    for line in lines:
        m = _DEF_RE.match(line)
        if m:
            name, dt, dims = m.groups()
            table[name] = (
                dt, [int(d) for d in dims.split(",")] if dims else []
            )
    return table


def _nbytes(dt: str, dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n * _DTYPE_BYTES.get(dt, 0)


def hlo_compute_stats(hlo_text: str) -> Dict[str, int]:
    """Trip-count-aware dot(+conv) FLOPs and matmul HBM bytes.

    XLA's ``compiled.cost_analysis()`` does NOT multiply while-loop bodies by
    their trip count (observed: 4.8 TF for a program whose layer scan alone
    is ~25 TF), so we count matmul FLOPs and operand/output bytes ourselves,
    walking the call graph the same way collective_stats does.  Elementwise
    FLOPs are ignored (matmuls dominate >10x); bytes are a matmul-traffic
    lower bound on HBM traffic (fusions stream everything else through the
    same tiles)."""
    comps = _split_computations(hlo_text)
    entry = comps.get("__entry__")
    tables = {name: _symbol_table(lines) for name, lines in comps.items()}

    def line_stats(line: str, table) -> Tuple[int, int]:
        md = _DEF_RE.match(line)
        out_dt, out_dims = (md.group(2),
                            [int(d) for d in md.group(3).split(",")] if md and md.group(3) else []) \
            if md else (None, [])
        if " dot(" in line:
            m = _DOT_LINE_RE.search(line)
            if not (m and md):
                return 0, 0
            operands = _OPERANDS_RE.findall(m.group(1))
            if not operands or operands[0] not in table:
                return 0, 0
            lhs_dt, lhs_dims = table[operands[0]]
            csize = 1
            if m.group(2):
                for ci in m.group(2).split(","):
                    idx = int(ci)
                    if idx < len(lhs_dims):
                        csize *= lhs_dims[idx]
            out_size = 1
            for d in out_dims:
                out_size *= d
            flops = 2 * out_size * csize
            nbytes = _nbytes(out_dt, out_dims)
            for op in operands[:2]:
                if op in table:
                    nbytes += _nbytes(*table[op])
            return flops, nbytes
        if " convolution(" in line and md:
            m = _CONV_LINE_RE.search(line)
            if not m:
                return 0, 0
            operands = _OPERANDS_RE.findall(m.group(1))
            out_size = 1
            for d in out_dims:
                out_size *= d
            k_size = 1
            if len(operands) > 1 and operands[1] in table:
                _, k_dims = table[operands[1]]
                for d in k_dims[:-1]:
                    k_size *= d
            nbytes = _nbytes(out_dt, out_dims)
            for op in operands[:2]:
                if op in table:
                    nbytes += _nbytes(*table[op])
            return 2 * out_size * k_size, nbytes
        return 0, 0

    if entry is None:
        table = _symbol_table(hlo_text.splitlines())
        f = b = 0
        for l in hlo_text.splitlines():
            lf, lb = line_stats(l, table)
            f += lf
            b += lb
        return {"dot_flops": f, "dot_bytes": b}

    seen: List[str] = []

    def visit(comp_name: str, mult: int):
        lines = comps[comp_name]
        table = tables[comp_name]
        f = b = 0
        for line in lines:
            lf, lb = line_stats(line, table)
            if lf:
                f += lf * mult
                b += lb * mult
                continue
            wm = _WHILE_RE.search(line)
            if wm:
                body = wm.group(1)
                tm = _TRIP_RE.search(line)
                trips = int(tm.group(1)) if tm else 1
                if body in comps and body not in seen:
                    seen.append(body)
                    sf, sb = visit(body, mult * trips)
                    f += sf
                    b += sb
                    seen.pop()
                continue
            km = _CALL_RE.search(line)
            if km and km.group(1) in comps and km.group(1) not in seen:
                seen.append(km.group(1))
                sf, sb = visit(km.group(1), mult)
                f += sf
                b += sb
                seen.pop()
                continue
            fm = re.search(r"fusion\(.*?calls=%?([\w\.\-]+)", line)
            if fm and fm.group(1) in comps and fm.group(1) not in seen:
                seen.append(fm.group(1))
                sf, sb = visit(fm.group(1), mult)
                f += sf
                b += sb
                seen.pop()
        return f, b

    f, b = visit("__entry__", 1)
    return {"dot_flops": f, "dot_bytes": b}


def decode_per_token_stats(hlo_text: str, batch: int) -> Dict[str, float]:
    """Modeled cost of ONE decoded token from a decode-step program.

    A decode step advances every sequence in the batch by exactly one token,
    so per-token cost is the program total divided by the batch — the
    serving analogue of the round kernels' modeled-bytes rows.  Feeding a
    prefill/train program in gives per-*step-row* numbers, which is not the
    same thing; only use decode-step HLO here."""
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    comp = hlo_compute_stats(hlo_text)
    coll = collective_stats(hlo_text)
    return {
        "dot_flops_per_token": comp["dot_flops"] / batch,
        "dot_bytes_per_token": comp["dot_bytes"] / batch,
        "collective_bytes_per_token": coll.total_bytes / batch,
    }


def roofline_terms(
    *,
    flops: float,
    bytes_accessed: float,
    collective_bytes: float,
    chips: int,
) -> Dict[str, float]:
    """The three §Roofline terms, in seconds (per step, whole mesh).

    flops / bytes_accessed are cost_analysis() *per-device* numbers times
    `chips` when aggregated by the caller; here we take WHOLE-PROGRAM totals
    and divide by the mesh."""
    compute_s = flops / (chips * PEAK_FLOPS)
    memory_s = bytes_accessed / (chips * HBM_BW)
    collective_s = collective_bytes / (chips * ICI_BW)
    dominant = max(
        ("compute", compute_s), ("memory", memory_s),
        ("collective", collective_s), key=lambda kv: kv[1],
    )[0]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
    }
