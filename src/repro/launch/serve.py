"""Serving CLI: continuous-batching engine (default) or the static-batch
baseline over the same compiled prefill/decode steps.

  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
      --slots 4 --requests 16 --rate 20 --max-len 96

``--static`` switches the admission policy to the legacy whole-batch
barrier (all requests of a batch start and finish together) — the baseline
``BENCH_serve.json`` compares against.  The heavy lifting lives in
``repro.serve``; this module only parses flags, builds the trace, and
prints the measured metrics.
"""
from __future__ import annotations

import argparse
import json

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--slots", type=int, default=4,
                    help="decode-batch slot capacity")
    ap.add_argument("--max-len", type=int, default=96,
                    help="KV cache length (prompt + generation budget)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=20.0,
                    help="Poisson arrival rate (requests/s)")
    ap.add_argument("--prompt-lens", type=int, nargs="+",
                    default=[16, 32, 48, 64])
    ap.add_argument("--gen-lens", type=int, nargs="+", default=[8, 16, 32])
    ap.add_argument("--static", action="store_true",
                    help="static-batch baseline admission policy")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import registry
    from repro.serve import ServeEngine, make_poisson_trace

    cfg = (registry.smoke_config(args.arch) if args.smoke
           else registry.get_config(args.arch))
    if not cfg.is_decoder():
        raise SystemExit(f"{args.arch} is encoder-only: no decode step")
    need = max(args.prompt_lens) + max(args.gen_lens) - 1
    if need > args.max_len:
        raise SystemExit(
            f"--max-len {args.max_len} too small for prompt+gen {need}")

    from repro.models import init_model

    params = init_model(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, num_slots=args.slots,
                         max_len=args.max_len)
    trace = make_poisson_trace(
        num_requests=args.requests, rate=args.rate,
        prompt_lens=args.prompt_lens, gen_lens=args.gen_lens,
        vocab_size=cfg.vocab_size, seed=args.seed,
    )
    engine.warmup(args.prompt_lens)

    policy = "static" if args.static else "continuous"
    report = engine.run(trace, policy=policy)
    m = report.metrics()
    print(f"# {policy} serving, {args.arch}"
          f"{' (smoke)' if args.smoke else ''}, slots={args.slots}")
    print(json.dumps(m, indent=2))
    sample = report.results[0]
    print("sample token ids:", sample.tokens[:16])


if __name__ == "__main__":
    main()
