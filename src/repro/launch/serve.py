"""Batched serving driver: prefill a batch of requests, then decode tokens
with the same sharded decode step the dry-run compiles.

  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    from repro.configs import registry
    from repro.launch.mesh import make_host_mesh
    from repro.launch.shardings import ShardingPolicy
    from repro.launch.steps import make_decode_step, make_prefill_step
    from repro.models import init_model
    from repro.models.transformer import Batch

    cfg = (registry.smoke_config(args.arch) if args.smoke
           else registry.get_config(args.arch))
    if not cfg.is_decoder():
        raise SystemExit(f"{args.arch} is encoder-only: no decode step")
    mesh = make_host_mesh(1, 1)
    pol = ShardingPolicy(dp_axes=("data",), dp_sizes=(1,), model_axis_size=1, fsdp=False)

    params = init_model(jax.random.PRNGKey(0), cfg)
    max_len = args.prompt_len + args.gen
    prefill = jax.jit(make_prefill_step(cfg, mesh, pol, max_len=max_len))
    decode = jax.jit(make_decode_step(cfg, mesh, pol))

    rng = np.random.default_rng(0)
    B, S = args.batch, args.prompt_len
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = Batch(
        tokens=prompts,
        positions=jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S)),
        targets=jnp.zeros((B, S), jnp.int32),
        loss_mask=jnp.ones((B, S), jnp.float32),
    )
    if cfg.rope == "mrope":
        batch = batch._replace(
            positions=jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None, None], (3, B, S)
            ),
            embeds=jnp.zeros((B, S, cfg.d_model), jnp.dtype(cfg.dtype)),
            embed_mask=jnp.zeros((B, S), bool),
        )

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    print(f"prefill {B}x{S}: {time.perf_counter()-t0:.2f}s")

    toks = [next_tok]
    t0 = time.perf_counter()
    for i in range(args.gen):
        pos = jnp.full((B,), S + i, jnp.int32)
        mrope = (jnp.broadcast_to(pos[None, :, None], (3, B, 1))
                 if cfg.rope == "mrope" else None)
        next_tok, logits, cache = decode(params, toks[-1], pos, cache, mrope)
        toks.append(next_tok)
    dt = time.perf_counter() - t0
    out = jnp.concatenate(toks, axis=1)
    print(f"decoded {args.gen} tokens x {B} reqs in {dt:.2f}s "
          f"({B*args.gen/dt:.1f} tok/s)")
    print("sample token ids:", np.asarray(out[0])[:16])


if __name__ == "__main__":
    main()
