"""Production mesh construction (multi-pod dry-run spec).

Functions, not module-level constants: importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU demos)."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"need {data*model} devices, have {n}")
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh (includes 'pod' when present)."""
    names = mesh.axis_names
    return tuple(a for a in names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"
