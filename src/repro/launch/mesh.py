"""Production mesh construction (multi-pod dry-run spec) + the round engine's
data mesh.

Functions, not module-level constants: importing this module never touches
jax device state.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np


def _axis_type_kwargs(n: int) -> dict:
    """``axis_types=(Auto,)*n`` where the running jax has AxisType; {} on
    older versions (pre-0.5 ``make_mesh`` has no such kwarg)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU demos)."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"need {data*model} devices, have {n}")
    return jax.make_mesh((data, model), ("data", "model"),
                         **_axis_type_kwargs(2))


ROUND_AXIS = "data"   # the axis the round engine shards clients / D over


def make_round_mesh(num_devices: Optional[int] = None):
    """1-D ``("data",)`` mesh over the first ``num_devices`` devices — the
    mesh the sharded round stages (``local_sgd_sharded`` /
    ``fused_int8_sharded``) shard over.

    Built directly from a device slice (not ``jax.make_mesh``) so a test can
    hold 1-, 2- and 8-device meshes of one forced-device CPU process at
    once."""
    devs = jax.devices()
    n = len(devs) if num_devices is None else num_devices
    if n > len(devs):
        raise ValueError(f"need {n} devices, have {len(devs)}")
    return jax.sharding.Mesh(np.array(devs[:n]), (ROUND_AXIS,))


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh (includes 'pod' when present)."""
    names = mesh.axis_names
    return tuple(a for a in names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"
