"""Sharding policy: PartitionSpecs for parameters, batches and caches.

Baseline policy (recorded as such in EXPERIMENTS.md §Perf):

* tensor parallelism over ``model``: attention heads / FFN hidden / experts /
  vocab;
* FSDP over ``data`` (+``pod``): the other big matrix dim, so giant models
  (Jamba-398B) fit — per-layer all-gathers are the cost, which the perf pass
  then attacks (small models: FSDP off is one of the §Perf levers);
* batch over the data axes; ``long_500k`` (batch=1) shards the KV-cache
  sequence axis instead.

Rules are (parent-context, leaf-name)-keyed, applied over the param pytree;
leaves under the scanned ``units`` stack get a leading ``None`` axis.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class ShardingPolicy:
    fsdp: bool = True
    dp_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    model_axis_size: int = 16
    dp_sizes: Tuple[int, ...] = (16,)   # aligned with dp_axes
    # shard experts' big dims over data (FSDP) as well (perf lever)
    shard_moe_fsdp: bool = True
    # sequence-parallel residual stream: activations (B,S,D) keep S sharded
    # over the model axis between layers (perf lever; attention-only archs)
    seq_parallel_acts: bool = False
    # 2D expert parallelism: expert Fv stays sliced over data inside the MoE
    # shard_map (tokens gathered instead of weights)
    moe_tp_over_dp: bool = False
    # model-dim-sharded residual stream (RWKV: token shift is over time, so a
    # D-sharded residual is legal and turns TP all-reduces into local math)
    act_shard_d: bool = False

    @property
    def fsdp_axis(self):
        return self.dp_axes if self.fsdp else None

    def axis_size(self, entry) -> int:
        """Product of mesh-axis sizes for one PartitionSpec entry."""
        if entry is None:
            return 1
        names = entry if isinstance(entry, (tuple, list)) else (entry,)
        sizes = dict(zip(self.dp_axes, self.dp_sizes))
        sizes[self.model_axis] = self.model_axis_size
        n = 1
        for a in names:
            n *= sizes.get(a, 1)
        return n


def _param_rule(owner: str, name: str, pol: ShardingPolicy) -> Optional[P]:
    M, F = pol.model_axis, pol.fsdp_axis
    moe_f = F if pol.shard_moe_fsdp else None
    col2 = P(F, M)           # (in, out): out over model, in over fsdp
    row2 = P(M, F)           # (in, out): in over model
    table = {
        ("top", "embed"): P(M, F),
        ("top", "lm_head"): P(F, M),
        ("mixer", "wq"): col2,
        ("mixer", "wk"): col2,
        ("mixer", "wv"): col2,
        ("mixer", "wg"): col2,
        ("mixer", "wr"): col2,
        ("mixer", "wo"): row2,
        ("mixer", "bq"): P(M),
        ("mixer", "bk"): P(M),
        ("mixer", "bv"): P(M),
        ("mixer", "in_proj"): col2,
        ("mixer", "out_proj"): row2,
        ("mixer", "x_proj"): P(M, None),
        ("mixer", "dt_proj"): P(None, M),
        ("mixer", "dt_bias"): P(M),
        ("mixer", "conv_w"): P(None, M),
        ("mixer", "conv_b"): P(M),
        ("mixer", "A_log"): P(M, None),
        ("mixer", "D"): P(M),
        # RWKV DDLoRA weights are tiny (<3 MB) — sharding their output dim
        # over `model` forced a (B,S,D) activation all-gather per interpolant
        # per layer (656 GB/device/step on rwkv6 train, §Perf H2). Replicate.
        ("mixer", "mix_w1"): P(),
        ("mixer", "mix_w2"): P(),
        ("mixer", "decay_w2"): P(),
        ("mlp", "gate"): col2,
        ("mlp", "up"): col2,
        ("mlp", "down"): row2,
        ("mlp", "wk"): col2,
        ("mlp", "wv"): row2,
        ("mlp", "wr"): col2,
        ("mlp", "router"): P(F, None),
        # MoE expert weights (V, D, Fv) / (V, Fv, D): experts over model.
        # tp_over_dp slices Fv over data (matches the shard_map in_specs,
        # so no per-layer resharding); otherwise FSDP goes on the other dim.
        ("mlp", "moe_up"): P(M, None, moe_f) if pol.moe_tp_over_dp
        else P(M, moe_f, None),
        ("mlp", "moe_gate"): P(M, None, moe_f) if pol.moe_tp_over_dp
        else P(M, moe_f, None),
        ("mlp", "moe_down"): P(M, moe_f, None) if pol.moe_tp_over_dp
        else P(M, None, moe_f),
    }
    return table.get((owner, name))


def _leaf_spec(name: str, leaf, owner: str, under_units: bool,
               pol: ShardingPolicy) -> P:
    lead = (None,) if under_units else ()
    base = leaf.ndim - len(lead)
    is_moe = owner == "mlp" and name in ("up", "gate", "down") and base == 3
    key = f"moe_{name}" if is_moe else name
    spec = _param_rule(owner, key, pol)
    if spec is None or len(spec) > base:
        spec = P()  # replicate (norms, small vectors, unknown leaves)
    parts = lead + tuple(spec) + (None,) * (base - len(spec))
    parts = parts[: leaf.ndim]
    # divisibility guard: drop sharding on dims the mesh axis doesn't divide
    # (e.g. HuBERT's 504-class head on a 16-way model axis)
    shape = leaf.shape
    parts = tuple(
        e if shape[i] % pol.axis_size(e) == 0 else None
        for i, e in enumerate(parts)
    )
    return P(*parts)


def _walk_layer(layer: dict, pol: ShardingPolicy, under_units: bool) -> dict:
    out = {}
    for part, sub in layer.items():
        if part in ("mixer", "mlp"):
            sub_out = {}
            for name, leaf in sub.items():
                if isinstance(leaf, dict):
                    sub_out[name] = jax.tree.map(lambda x: P(), leaf)
                else:
                    sub_out[name] = _leaf_spec(name, leaf, part, under_units, pol)
            out[part] = sub_out
        else:  # norm1 / norm2
            out[part] = jax.tree.map(lambda x: P(), sub)
    return out


def param_pspecs(cfg: ModelConfig, params: dict, pol: ShardingPolicy) -> dict:
    """PartitionSpec pytree matching `params` (arrays or ShapeDtypeStructs)."""
    out = {}
    for k, v in params.items():
        if k == "units":
            out[k] = tuple(_walk_layer(lp, pol, True) for lp in v)
        elif k == "tail":
            out[k] = tuple(_walk_layer(lp, pol, False) for lp in v)
        elif isinstance(v, dict):
            out[k] = jax.tree.map(lambda x: P(), v)
        else:
            out[k] = _leaf_spec(k, v, "top", False, pol)
    return out


# ----------------------------------------------------------------------------
# batch / cache specs
# ----------------------------------------------------------------------------


def batch_pspecs(cfg: ModelConfig, pol: ShardingPolicy, *, batch_sharded: bool):
    from repro.models.transformer import Batch

    dp = pol.dp_axes if batch_sharded else None
    pos = P(None, dp, None) if cfg.rope == "mrope" else P(dp, None)
    return Batch(
        tokens=None if cfg.frontend == "audio" else P(dp, None),
        embeds=P(dp, None, None) if cfg.frontend else None,
        embed_mask=P(dp, None) if cfg.frontend else None,
        positions=pos,
        targets=P(dp, None),
        loss_mask=P(dp, None),
    )


def cache_pspecs(cfg: ModelConfig, cache, pol: ShardingPolicy,
                 *, batch_sharded: bool):
    """Specs for the decode cache pytree.

    attn k/v (B, L, Kv, hd): batch over dp; kv-heads over model when
    divisible by the model axis, else the sequence axis takes the model
    axis.  batch=1 (long_500k): sequence over data (+ model when kv heads
    don't shard)."""
    M = pol.model_axis
    msize = pol.model_axis_size
    dp = pol.dp_axes if batch_sharded else None
    kv_over_model = cfg.num_kv_heads % msize == 0 and cfg.num_kv_heads > 0
    rwkv_heads = cfg.d_model // max(cfg.rwkv_head_dim, 1)
    h_over_model = rwkv_heads % msize == 0

    if batch_sharded:
        seq_axes = M if not kv_over_model else None
    else:
        seq_axes = ("data", M) if not kv_over_model else ("data",)

    def leaf_spec(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "name", p))) for p in path]
        name = names[-1]
        under_units = "units" in names
        lead = (None,) if under_units else ()
        if name in ("k", "v"):
            return P(*lead, dp, seq_axes, M if kv_over_model else None, None)
        if name == "pos":
            return P(*lead, dp, seq_axes)
        if name == "conv":
            return P(*lead, dp, None, M)
        if name == "ssm":
            return P(*lead, dp, M, None)
        if name == "shift":
            return P(*lead, dp, None)
        if name == "wkv":
            return P(*lead, dp, M if h_over_model else None, None, None)
        base = leaf.ndim - len(lead)
        return P(*lead, *([None] * base))

    return jax.tree_util.tree_map_with_path(leaf_spec, cache)


def named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ----------------------------------------------------------------------------
# round-engine specs (the BFLC sharded stages, repro.fl.sharded)
# ----------------------------------------------------------------------------


def round_engine_pspecs(axis: str = "data") -> dict:
    """The sharded round engine's data layout, in one place:

    * ``clients``    — client-stacked leaves (P, ...): P over the data axis
      (local-training batches in, update stacks out);
    * ``dshard``     — (K, Dpad) int8 stack and (K, nblk) scales: D over the
      data axis (each device quantizes/reduces its slice);
    * ``dvec``       — (Dpad,) aggregated flat update: D over the data axis
      (all-gathered into the model block at first replicated use);
    * ``replicated`` — global params and the (K,) weight vector.

    The shard_mapped programs (repro.fl.client / repro.kernels.ops) encode
    exactly these specs; the differential test harness asserts the arrays
    they produce actually carry them."""
    return {
        "clients": P(axis),
        "dshard": P(None, axis),
        "dvec": P(axis),
        "replicated": P(),
    }


def score_matrix_pspecs(axis: str = "data") -> dict:
    """The sharded committee-validation engine's data layout (the P x Q
    score matrix of paper §III.B, sharded stage `committee_sharded`):

    * ``updates``    — candidate-stacked leaves (P, ...): P over the data
      axis (update rows arrive P-sharded straight from the trainer);
    * ``int8_rows``  — (P, Dpad) int8 rows + (P, nblk) scales of the fused
      score-from-int8 path: P over the data axis (each device quantizes
      and rebuilds its own candidate rows; tiles are row-local, so blobs
      coincide with the single-device chain codec);
    * ``scores``     — the (P, Q) score matrix: P over the data axis —
      the ONLY array gathered at the validate stage boundary;
    * ``replicated`` — global params and the (Q, vb, ...) member val
      batches.

    ``make_sharded_score_matrix_fn`` / ``make_sharded_score_from_int8_fn``
    (repro.fl.client) encode exactly these specs; the differential test
    harness asserts the arrays they produce actually carry them."""
    return {
        "updates": P(axis),
        "int8_rows": P(axis),
        "scores": P(axis),
        "replicated": P(),
    }
