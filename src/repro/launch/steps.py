"""Sharded train / prefill / decode steps for the production mesh.

Two training flavours:

* ``standard`` — plain token-mean cross-entropy (the Basic-FL / centralized
  baseline at scale).
* ``bflc``     — the paper's technique as a first-class distributed feature:
  the global batch is split into **cohorts** (the production analogue of FL
  trainer nodes — one cohort per data-axis slice by default) and a
  **committee of validation shards** scores each cohort; the median member
  score gates/weights each cohort's loss contribution, so the aggregated
  gradient is exactly the committee-weighted FedAvg of per-cohort gradients
  (gradient linearity), computed by GSPMD with no manual collectives.
  Scoring follows §III.B adapted to in-graph form (DESIGN.md §4): member j
  scores cohort c by -|loss_c - val_loss_j| similarity, median over j,
  softmax over cohorts.  Malicious/poisoned cohorts show anomalous loss and
  are downweighted — the same robustness mechanism the FL runtime implements
  exactly at node granularity.

All steps take explicit in/out shardings from shardings.py and are meant to
be ``jax.jit(...).lower(...).compile()``-ed by launch/dryrun.py.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import dp_axes as mesh_dp_axes
from repro.launch.shardings import ShardingPolicy
from repro.models import decode_step as model_decode_step
from repro.models import forward, prefill as model_prefill
from repro.models.config import ModelConfig
from repro.models.moe import MoEShardingCtx
from repro.models.transformer import Batch
from repro.optim import Optimizer


def make_moe_ctx(cfg: ModelConfig, mesh, pol: ShardingPolicy,
                 *, batch_sharded: bool):
    """Builds the ShardCtx (activation constraints + MoE mesh context)."""
    from repro.models.shardctx import make_shard_ctx

    moe = None
    if cfg.num_experts:
        moe = MoEShardingCtx(
            mesh=mesh,
            dp_axes=pol.dp_axes,
            model_axis=pol.model_axis,
            batch_sharded=batch_sharded,
            tp_over_dp=pol.moe_tp_over_dp,
        )
    return make_shard_ctx(
        mesh, pol.dp_axes, pol.model_axis,
        batch_sharded=batch_sharded, moe=moe,
        num_kv_heads=cfg.num_kv_heads, num_heads=cfg.num_heads,
        seq_parallel=pol.seq_parallel_acts and batch_sharded,
        act_shard_d=getattr(pol, "act_shard_d", False) and batch_sharded,
    )


# ----------------------------------------------------------------------------
# losses
# ----------------------------------------------------------------------------


def token_ce(logits, targets, loss_mask):
    """Per-token NLL.  Written as logsumexp - one_hot·logits (not
    take_along_axis) so a model-sharded vocab axis reduces with psums instead
    of an all-gather of the full logits."""
    z = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(z, axis=-1, keepdims=True))
    z = z - m
    lse = jnp.log(jnp.sum(jnp.exp(z), axis=-1))
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=z.dtype)
    tgt = jnp.einsum("...v,...v->...", z, onehot)
    nll = lse - tgt
    mask = loss_mask.astype(jnp.float32)
    return nll * mask, mask


def standard_loss(params, cfg, batch: Batch, ctx):
    logits, aux = forward(params, cfg, batch, ctx)
    nll, mask = token_ce(logits, batch.targets, batch.loss_mask)
    loss = nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return loss + aux, loss


def bflc_loss(params, cfg, batch: Batch, val_batch: Batch, ctx,
              num_cohorts: int, committee_size: int):
    """Committee-weighted cohort loss (the paper's technique, in-graph)."""
    logits, aux = forward(params, cfg, batch, ctx)
    nll, mask = token_ce(logits, batch.targets, batch.loss_mask)
    B = nll.shape[0]
    nll_c = nll.reshape(num_cohorts, B // num_cohorts, -1)
    mask_c = mask.reshape(num_cohorts, B // num_cohorts, -1)
    cohort_loss = nll_c.sum(axis=(1, 2)) / jnp.maximum(
        mask_c.sum(axis=(1, 2)), 1.0
    )                                                    # (C,)

    # committee validation shards: per-member mean loss under stop_gradient
    vlogits, _ = forward(
        jax.lax.stop_gradient(params), cfg, val_batch, ctx
    )
    vnll, vmask = token_ce(vlogits, val_batch.targets, val_batch.loss_mask)
    member_loss = vnll.sum(axis=-1) / jnp.maximum(vmask.sum(axis=-1), 1.0)
    member_loss = member_loss[:committee_size]           # (Q,)

    # member j's score for cohort c: -|loss_c - val_loss_j|; median over j
    cl = jax.lax.stop_gradient(cohort_loss)
    scores = -jnp.abs(cl[:, None] - member_loss[None, :])   # (C, Q)
    med = jnp.median(scores, axis=1)                        # (C,)
    weights = jax.nn.softmax(med / jnp.maximum(med.std(), 1e-6))
    weights = jax.lax.stop_gradient(weights)

    loss = jnp.sum(weights * cohort_loss)
    return loss + aux, loss


# ----------------------------------------------------------------------------
# train step
# ----------------------------------------------------------------------------


class TrainState(NamedTuple):
    params: dict
    opt_state: dict
    step: jnp.ndarray


def _split_microbatches(batch: Batch, mb: int) -> Batch:
    """Reshape every field's batch dim B -> (mb, B/mb); M-RoPE positions
    (3,B,S) split on axis 1."""

    def split(name, x):
        if x is None:
            return None
        if name == "positions" and x.ndim == 3:
            return jnp.moveaxis(
                x.reshape(x.shape[0], mb, -1, x.shape[2]), 1, 0
            )
        return x.reshape(mb, -1, *x.shape[1:])

    return Batch(**{k: split(k, v) for k, v in batch._asdict().items()})


def make_train_step(
    cfg: ModelConfig,
    optimizer: Optimizer,
    mesh,
    pol: ShardingPolicy,
    *,
    mode: str = "bflc",
    num_cohorts: int = 16,
    committee_size: int = 8,
    num_microbatches: int = 1,
):
    ctx = make_moe_ctx(cfg, mesh, pol, batch_sharded=True)

    def loss_for(p, b: Batch, val_batch):
        if mode == "bflc":
            return bflc_loss(p, cfg, b, val_batch, ctx,
                             num_cohorts, committee_size)
        return standard_loss(p, cfg, b, ctx)

    def train_step(state: TrainState, batch: Batch,
                   val_batch: Optional[Batch] = None):
        if num_microbatches == 1:
            (total, ce), grads = jax.value_and_grad(
                lambda p: loss_for(p, batch, val_batch), has_aux=True
            )(state.params)
        else:
            # gradient accumulation: activation memory scales 1/mb (§Perf H3)
            mbs = _split_microbatches(batch, num_microbatches)
            gacc0 = jax.tree.map(jnp.zeros_like, state.params)

            def body(gacc, mb_batch):
                (tot, ce_mb), g = jax.value_and_grad(
                    lambda p: loss_for(p, mb_batch, val_batch), has_aux=True
                )(state.params)
                gacc = jax.tree.map(
                    lambda a, gg: a + (gg / num_microbatches).astype(a.dtype),
                    gacc, g,
                )
                return gacc, (tot, ce_mb)

            grads, (totals, ces) = jax.lax.scan(body, gacc0, mbs)
            total, ce = totals.mean(), ces.mean()
        new_params, new_opt = optimizer.update(
            grads, state.opt_state, state.params, state.step
        )
        return TrainState(new_params, new_opt, state.step + 1), {
            "loss": ce,
            "total_loss": total,
        }

    return train_step


# ----------------------------------------------------------------------------
# serving steps
# ----------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig, mesh, pol: ShardingPolicy,
                      max_len: int, *, batch_sharded: bool = True):
    ctx = make_moe_ctx(cfg, mesh, pol, batch_sharded=batch_sharded)

    def prefill_step(params, batch: Batch):
        return model_prefill(params, cfg, batch, max_len, ctx)

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh, pol: ShardingPolicy,
                     *, batch_sharded: bool = True,
                     return_logits: bool = True):
    """One greedy decode step.

    ``return_logits=False`` drops the (B, 1, V) logits from the outputs —
    the serving hot loop only needs the argmax token, and materializing /
    transferring full logits every tick is pure overhead (the continuous
    engine jits this with the token/position/cache buffers donated, so the
    step updates the KV cache in place).
    """
    ctx = make_moe_ctx(cfg, mesh, pol, batch_sharded=batch_sharded)

    def serve_step(params, tokens, position, cache,
                   mrope_position=None):
        logits, new_cache = model_decode_step(
            params, cfg, tokens, position, cache, ctx,
            mrope_position=mrope_position,
        )
        next_token = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        if return_logits:
            return next_token[:, None], logits, new_cache
        return next_token[:, None], new_cache

    return serve_step
