"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination with 512 placeholder host devices, print memory/cost analysis,
and dump the roofline record for EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
  PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes
"""
# The first two lines MUST run before any other import touches jax: jax locks
# the device count on first initialization.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.launch import hlo_stats
from repro.launch.mesh import dp_axes as mesh_dp_axes, make_production_mesh
from repro.launch.shardings import (
    ShardingPolicy,
    batch_pspecs,
    cache_pspecs,
    named,
    param_pspecs,
)
from repro.launch.steps import (
    TrainState,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
from repro.models import init_cache, init_model
from repro.models.config import ModelConfig
from repro.models.moe import virtual_factor
from repro.models.transformer import Batch
from repro.optim import adamw, linear_warmup_cosine

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def shape_applicable(cfg: ModelConfig, shape: str) -> Optional[str]:
    """None if runnable, else the skip reason (DESIGN.md §5)."""
    if shape in ("decode_32k", "long_500k") and not cfg.is_decoder():
        return "encoder-only: no decode step"
    if shape == "long_500k" and not cfg.is_subquadratic():
        return "pure full attention: 500k decode cache unbounded"
    return None


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def make_input_specs(cfg: ModelConfig, shape: str, *, val_rows: int = 0):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    info = SHAPES[shape]
    B, S = info["batch"], info["seq"]
    dt = jnp.dtype(cfg.dtype)
    if info["kind"] in ("train", "prefill"):
        if cfg.frontend == "audio":
            batch = Batch(
                tokens=None,
                embeds=sds((B, S, cfg.d_model), dt),
                embed_mask=sds((B, S), jnp.bool_),
                positions=sds((B, S), jnp.int32),
                targets=sds((B, S), jnp.int32),
                loss_mask=sds((B, S), jnp.float32),
            )
        elif cfg.frontend == "vision":
            batch = Batch(
                tokens=sds((B, S), jnp.int32),
                embeds=sds((B, S, cfg.d_model), dt),
                embed_mask=sds((B, S), jnp.bool_),
                positions=sds((3, B, S), jnp.int32),
                targets=sds((B, S), jnp.int32),
                loss_mask=sds((B, S), jnp.float32),
            )
        else:
            batch = Batch(
                tokens=sds((B, S), jnp.int32),
                embeds=None,
                embed_mask=None,
                positions=sds((B, S), jnp.int32),
                targets=sds((B, S), jnp.int32),
                loss_mask=sds((B, S), jnp.float32),
            )
        return batch
    # decode
    tokens = sds((B, 1), jnp.int32)
    position = sds((B,), jnp.int32)
    cache = jax.eval_shape(
        lambda: init_cache(cfg, B, S, jnp.dtype(cfg.dtype))
    )
    mrope = sds((3, B, 1), jnp.int32) if cfg.rope == "mrope" else None
    return tokens, position, cache, mrope


def make_val_batch_specs(cfg: ModelConfig, rows: int, seq: int = 1024):
    dt = jnp.dtype(cfg.dtype)
    if cfg.frontend == "audio":
        return Batch(
            tokens=None,
            embeds=sds((rows, seq, cfg.d_model), dt),
            embed_mask=sds((rows, seq), jnp.bool_),
            positions=sds((rows, seq), jnp.int32),
            targets=sds((rows, seq), jnp.int32),
            loss_mask=sds((rows, seq), jnp.float32),
        )
    if cfg.frontend == "vision":
        return Batch(
            tokens=sds((rows, seq), jnp.int32),
            embeds=sds((rows, seq, cfg.d_model), dt),
            embed_mask=sds((rows, seq), jnp.bool_),
            positions=sds((3, rows, seq), jnp.int32),
            targets=sds((rows, seq), jnp.int32),
            loss_mask=sds((rows, seq), jnp.float32),
        )
    return Batch(
        tokens=sds((rows, seq), jnp.int32),
        embeds=None,
        embed_mask=None,
        positions=sds((rows, seq), jnp.int32),
        targets=sds((rows, seq), jnp.int32),
        loss_mask=sds((rows, seq), jnp.float32),
    )


def dryrun_one(
    arch: str,
    shape: str,
    *,
    multi_pod: bool = False,
    mode: str = "bflc",
    policy_overrides: Optional[dict] = None,
    verbose: bool = True,
    save: bool = True,
    tag: str = "baseline",
    remat="unit",
    microbatches: int = 1,
) -> Dict:
    cfg = registry.get_config(
        arch, dtype="bfloat16",
        remat="layer" if remat == "layer" else True,
    )
    reason = shape_applicable(cfg, shape)
    rec = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": mode, "tag": tag,
    }
    if reason:
        rec["skipped"] = reason
        if verbose:
            print(f"[skip] {arch} x {shape}: {reason}")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    dp = mesh_dp_axes(mesh)
    pol = ShardingPolicy(
        dp_axes=dp,
        dp_sizes=tuple(mesh.shape[a] for a in dp),
        model_axis_size=mesh.shape["model"],
        **(policy_overrides or {}),
    )
    info = SHAPES[shape]
    virtual_r = (
        virtual_factor(cfg, mesh.shape["model"]) if cfg.num_experts else 1
    )

    params_shape = jax.eval_shape(
        lambda: init_model(jax.random.PRNGKey(0), cfg, virtual_r=virtual_r)
    )
    pspecs = param_pspecs(cfg, params_shape, pol)
    param_shardings = named(mesh, pspecs)

    t0 = time.perf_counter()
    try:
        if info["kind"] == "train":
            moment_dtype = (
                jnp.bfloat16 if registry.param_count(cfg) > 5e10 else None
            )
            opt = adamw(
                linear_warmup_cosine(3e-4, 100, 10_000),
                moment_dtype=moment_dtype, weight_decay=0.1,
            )
            opt_state_shape = jax.eval_shape(opt.init, params_shape)
            opt_pspecs = {"m": pspecs, "v": pspecs}
            dp_total = 1
            for a in dp:
                dp_total *= mesh.shape[a]
            step_fn = make_train_step(
                cfg, opt, mesh, pol, mode=mode,
                num_cohorts=dp_total, committee_size=dp_total,
                num_microbatches=microbatches,
            )
            batch = make_input_specs(cfg, shape)
            val_batch = (
                make_val_batch_specs(cfg, dp_total) if mode == "bflc" else None
            )
            bspec = batch_pspecs(cfg, pol, batch_sharded=True)
            state_shardings = TrainState(
                params=param_shardings,
                opt_state=named(mesh, opt_pspecs),
                step=NamedSharding(mesh, P()),
            )
            state_shape = TrainState(
                params=params_shape,
                opt_state=opt_state_shape,
                step=sds((), jnp.int32),
            )
            in_shardings = (
                state_shardings,
                named(mesh, bspec),
                named(mesh, batch_pspecs(cfg, pol, batch_sharded=True))
                if val_batch is not None else None,
            )
            out_shardings = (state_shardings, NamedSharding(mesh, P()))
            args = (state_shape, batch) + (
                (val_batch,) if val_batch is not None else (None,)
            )
            jitted = jax.jit(
                step_fn,
                in_shardings=(
                    in_shardings[0], in_shardings[1], in_shardings[2]
                ),
                out_shardings=out_shardings,
                donate_argnums=(0,),   # alias old->new TrainState buffers
            )
            lowered = jitted.lower(*args)
        elif info["kind"] == "prefill":
            step_fn = make_prefill_step(cfg, mesh, pol, max_len=info["seq"])
            batch = make_input_specs(cfg, shape)
            bspec = batch_pspecs(cfg, pol, batch_sharded=True)
            cache_shape = jax.eval_shape(
                lambda p, b: step_fn(p, b)[1], params_shape, batch
            ) if cfg.is_decoder() else None
            out_cache_shardings = (
                named(mesh, cache_pspecs(cfg, cache_shape, pol,
                                         batch_sharded=True))
                if cache_shape is not None else None
            )
            if cfg.is_decoder():
                jitted = jax.jit(
                    step_fn,
                    in_shardings=(param_shardings, named(mesh, bspec)),
                    out_shardings=(
                        NamedSharding(mesh, P()),
                        out_cache_shardings,
                    ),
                )
            else:
                # encoder: "prefill" = full-sequence encode (logits only)
                from repro.models import forward as fwd

                def encode(params, b):
                    return fwd(params, cfg, b)[0]

                jitted = jax.jit(
                    encode,
                    in_shardings=(param_shardings, named(mesh, bspec)),
                )
                step_fn = encode
            lowered = jitted.lower(params_shape, batch)
        else:  # decode
            B = info["batch"]
            batch_sharded = B > 1
            step_fn = make_decode_step(
                cfg, mesh, pol, batch_sharded=batch_sharded
            )
            tokens, position, cache, mrope = make_input_specs(cfg, shape)
            cspecs = cache_pspecs(cfg, cache, pol, batch_sharded=batch_sharded)
            dp_or_none = dp if batch_sharded else None
            tok_sh = NamedSharding(mesh, P(dp_or_none, None))
            pos_sh = NamedSharding(mesh, P(dp_or_none))
            mrope_sh = (
                NamedSharding(mesh, P(None, dp_or_none, None))
                if mrope is not None else None
            )
            jitted = jax.jit(
                step_fn,
                in_shardings=(
                    param_shardings, tok_sh, pos_sh, named(mesh, cspecs),
                    mrope_sh,
                ),
                out_shardings=(
                    tok_sh, NamedSharding(mesh, P(dp_or_none, None, "model")),
                    named(mesh, cspecs),
                ),
            )
            lowered = jitted.lower(params_shape, tokens, position, cache, mrope)

        compiled = lowered.compile()
        compile_s = time.perf_counter() - t0

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = hlo_stats.collective_stats(hlo)
        comp_stats = hlo_stats.hlo_compute_stats(hlo)
        # trip-count-aware matmul FLOPs/bytes (cost_analysis does not
        # multiply while-loop bodies — see hlo_stats.hlo_compute_stats)
        flops = float(comp_stats["dot_flops"])
        bytes_acc = max(
            float(cost.get("bytes accessed", 0.0)),
            float(comp_stats["dot_bytes"]),
        )
        terms = hlo_stats.roofline_terms(
            flops=flops, bytes_accessed=bytes_acc,
            collective_bytes=float(coll.total_bytes), chips=1,
        )  # all values are per-device post-SPMD; chips=1 keeps units right
        rec.update({
            "chips": chips,
            "compile_s": round(compile_s, 1),
            "flops_per_device": flops,
            "flops_cost_analysis": float(cost.get("flops", 0.0)),
            "bytes_per_device": bytes_acc,
            "dot_bytes_per_device": int(comp_stats["dot_bytes"]),
            "collective_bytes_per_device": int(coll.total_bytes),
            "collective_breakdown": coll.bytes_by_kind,
            "collective_counts": coll.count_by_kind,
            "peak_memory_per_device": getattr(
                mem, "temp_size_in_bytes", None
            ),
            "argument_size": getattr(mem, "argument_size_in_bytes", None),
            "output_size": getattr(mem, "output_size_in_bytes", None),
            "roofline": terms,
            "params": registry.param_count(cfg),
            "active_params": registry.active_param_count(cfg),
        })
        if verbose:
            print(
                f"[ok] {arch} x {shape} x {rec['mesh']} ({tag}): "
                f"compile {compile_s:.0f}s, "
                f"{flops/1e12:.2f} TF/dev, {bytes_acc/1e9:.2f} GB/dev, "
                f"coll {coll.total_bytes/1e9:.3f} GB/dev, "
                f"dominant={terms['dominant']}"
            )
            print(f"     memory_analysis: {mem}")
    except Exception as e:  # noqa: BLE001 — record failures, keep sweeping
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[FAIL] {arch} x {shape} x {rec['mesh']}: {rec['error']}")

    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        fname = f"{arch}_{shape}_{rec['mesh'].replace('x','-')}_{tag}.json"
        with open(os.path.join(OUT_DIR, fname), "w") as f:
            json.dump(rec, f, indent=2, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(registry.ARCH_IDS))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--mode", default="bflc", choices=["bflc", "standard"])
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--moe-2d", action="store_true")
    ap.add_argument("--remat", default="unit", choices=["unit", "layer"])
    ap.add_argument("--act-shard-d", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    pairs = []
    if args.all:
        for a in registry.ARCH_IDS:
            for s in SHAPES:
                pairs.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]

    overrides = {}
    if args.no_fsdp:
        overrides["fsdp"] = False
    if args.seq_parallel:
        overrides["seq_parallel_acts"] = True
    if args.moe_2d:
        overrides["moe_tp_over_dp"] = True
    if args.act_shard_d:
        overrides["act_shard_d"] = True
    overrides = overrides or None
    failures = 0
    for mp in meshes:
        for a, s in pairs:
            rec = dryrun_one(
                a, s, multi_pod=mp, mode=args.mode,
                policy_overrides=overrides, tag=args.tag,
                remat=args.remat, microbatches=args.microbatches,
            )
            failures += 1 if "error" in rec else 0
    print(f"\ndone; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
