"""End-to-end training driver.

Two entry modes:

* ``--driver fl``   — the paper's pipeline: BFLC over federated clients
  (synthetic FEMNIST-like data, CNN global model), with Basic-FL / CwMed /
  stand-alone comparisons.  This is the faithful-reproduction driver.
* ``--driver lm``   — the production pipeline scaled to this container: a
  ~100M-parameter decoder trained for a few hundred steps on synthetic
  Markov-chain data with the same sharded train_step the dry-run compiles
  (host mesh), in either ``standard`` or ``bflc`` (committee-weighted) mode.

Examples:
  PYTHONPATH=src python -m repro.launch.train --driver lm --steps 200
  PYTHONPATH=src python -m repro.launch.train --driver fl --rounds 30
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def lm_100m_config(vocab: int = 8192):
    from repro.models.config import ModelConfig, dense_unit

    return ModelConfig(
        name="repro-100m",
        arch_type="dense",
        d_model=768,
        vocab_size=vocab,
        unit=dense_unit(1),
        num_units=12,
        num_heads=12,
        num_kv_heads=4,
        d_ff=3072,
        remat=False,
    )


def run_lm(args):
    from repro.data.lm_synthetic import MarkovLM
    from repro.launch.mesh import make_host_mesh
    from repro.launch.shardings import (
        ShardingPolicy, batch_pspecs, named, param_pspecs,
    )
    from repro.launch.steps import TrainState, make_train_step
    from repro.models import init_model
    from repro.models.transformer import Batch
    from repro.optim import adamw, linear_warmup_cosine

    cfg = lm_100m_config(vocab=getattr(args, 'vocab', 8192))
    if args.small:
        cfg = cfg.replace(num_units=4, d_model=256, num_heads=8,
                          num_kv_heads=4, d_ff=1024)
    mesh = make_host_mesh(1, len(jax.devices()) if args.use_all_devices else 1)
    pol = ShardingPolicy(
        dp_axes=("data",), dp_sizes=(mesh.shape["data"],),
        model_axis_size=mesh.shape["model"], fsdp=False,
    )
    opt = adamw(linear_warmup_cosine(args.lr, 20, args.steps))
    params = init_model(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params, mesh {dict(mesh.shape)}")

    step_fn = make_train_step(
        cfg, opt, mesh, pol, mode=args.mode,
        num_cohorts=args.cohorts, committee_size=args.committee,
    )
    jstep = jax.jit(step_fn)
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))

    lm = MarkovLM(cfg.vocab_size, seed=1)
    rng = np.random.default_rng(0)
    print(f"chain entropy (loss floor): {lm.entropy():.3f} nats; "
          f"ln(V) = {np.log(cfg.vocab_size):.3f}")

    def make_batch(batch, seq):
        toks, tgts = lm.batch(rng, batch, seq)
        B, S = toks.shape
        return Batch(
            tokens=jnp.asarray(toks),
            positions=jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S)),
            targets=jnp.asarray(tgts),
            loss_mask=jnp.ones((B, S), jnp.float32),
        )

    t0 = time.perf_counter()
    for step in range(args.steps):
        batch = make_batch(args.batch, args.seq)
        val = make_batch(max(args.committee, 1), args.seq) \
            if args.mode == "bflc" else None
        state, metrics = jstep(state, batch, val)
        if (step + 1) % args.log_every == 0 or step == 0:
            print(f"step {step+1:4d}  loss {float(metrics['loss']):.4f}  "
                  f"({(time.perf_counter()-t0)/(step+1):.2f}s/step)")
    if args.ckpt:
        from repro.checkpoint import save_pytree
        save_pytree(args.ckpt, state.params)
        print("saved", args.ckpt)
    return float(metrics["loss"])


def run_fl(args):
    from repro.api import build_runtime
    from repro.data import make_femnist_like
    from repro.fl import femnist_adapter

    ds = make_femnist_like(
        num_clients=args.clients, mean_samples=80, test_size=1000, seed=1
    )
    adapter = femnist_adapter(width=16)
    rt = build_runtime(adapter, ds, dict(
        active_proportion=args.active, k_updates=args.k_updates,
        local_steps=args.local_steps, malicious_fraction=args.malicious,
        seed=args.seed,
    ))
    logs = rt.run(args.rounds, eval_every=args.log_every)
    for lg in logs:
        if lg.test_accuracy is not None:
            print(f"round {lg.round:3d}  acc {lg.test_accuracy:.4f}  "
                  f"packed_malicious {lg.packed_malicious}")
    assert rt.chain.verify(), "chain integrity violated"
    print(f"chain height {rt.chain.height}, verified OK")
    return logs[-1].test_accuracy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--driver", choices=["lm", "fl"], default="lm")
    # lm
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mode", choices=["standard", "bflc"], default="standard")
    ap.add_argument("--cohorts", type=int, default=4)
    ap.add_argument("--committee", type=int, default=4)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--use-all-devices", action="store_true")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log-every", type=int, default=10)
    # fl
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--active", type=float, default=0.2)
    ap.add_argument("--k-updates", type=int, default=8)
    ap.add_argument("--local-steps", type=int, default=20)
    ap.add_argument("--malicious", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.driver == "lm":
        run_lm(args)
    else:
        run_fl(args)


if __name__ == "__main__":
    main()
