"""Slot-based continuous-batching engine over the compiled serving steps.

A fixed-capacity decode batch of ``num_slots`` request slots runs ONE fused
decode step per tick (``launch/steps.make_decode_step`` with logits dropped
and the position/cache buffers donated).  Admission is prefill-into-slot:
a queued request is prefilled at its exact prompt length (batch 1) and its
KV state written into the freed slot row (``models.cache.insert_slot_cache``)
— no batch barrier, so short requests never wait on long ones.  Finished
slots free at the tick boundary on which their generation budget is spent;
finish detection is count-based, so the hot loop never blocks on token
values: each tick's token vector is fetched one tick late, while the next
tick is already in flight on device.

The engine also watches a ``ParamSource`` (live chain or checkpoint
directory — ``repro.serve.params``) and hot-swaps the whole parameter pytree
at a tick boundary when a new round commits a model block.  In-flight
requests keep their caches and keep decoding; nothing is dropped.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import make_host_mesh
from repro.launch.shardings import ShardingPolicy
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import init_cache
from repro.models.cache import insert_slot_cache
from repro.models.config import ModelConfig
from repro.models.transformer import Batch
from repro.serve.scheduler import FifoScheduler
from repro.serve.slots import Request, RequestResult, SlotTable
from repro.serve.trace import aggregate


# ----------------------------------------------------------------------------
# clocks
# ----------------------------------------------------------------------------


class WallClock:
    """Real time — the benchmark's clock."""

    def __init__(self):
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def tick(self) -> None:
        pass

    def advance_to(self, t: float) -> None:
        delta = t - self.now()
        if delta > 0:
            time.sleep(min(delta, 0.002))


class VirtualClock:
    """Deterministic tick-counting clock — the test harness's clock.

    Time advances ``dt`` per decode tick and jumps to the next arrival when
    the engine idles, so admission order (and therefore every decoded token)
    is reproducible run-to-run."""

    def __init__(self, dt: float = 1.0):
        self.dt = dt
        self._t = 0.0

    def now(self) -> float:
        return self._t

    def tick(self) -> None:
        self._t += self.dt

    def advance_to(self, t: float) -> None:
        if t > self._t:
            self._t = t


# ----------------------------------------------------------------------------
# engine
# ----------------------------------------------------------------------------


@dataclass
class _Pending:
    """A launched-but-not-fetched token vector: drained one tick late."""

    tok: Any                                      # device array (rows, 1)
    # (rid, row, is_first_token, is_last_token)
    deliveries: List[Tuple[int, int, bool, bool]]
    version: int


@dataclass
class ServeReport:
    results: List[RequestResult]
    wall_s: float
    ticks: int
    occupancy: float                              # mean active-slot fraction
    swaps: List[Dict[str, Any]]
    policy: str

    def metrics(self) -> Dict[str, float]:
        return aggregate(
            self.results, wall_s=self.wall_s, ticks=self.ticks,
            occupancy=self.occupancy, swaps=len(self.swaps),
        )

    def by_rid(self) -> Dict[int, RequestResult]:
        return {r.rid: r for r in self.results}


class ServeEngine:
    """Continuous-batching server for one decoder model."""

    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        *,
        num_slots: int = 4,
        max_len: int = 128,
        mesh=None,
        pol: Optional[ShardingPolicy] = None,
        param_source=None,
        swap_poll_every: int = 1,
    ):
        if not cfg.is_decoder():
            raise ValueError(f"{cfg.name} is encoder-only: nothing to serve")
        self.cfg = cfg
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.source = param_source
        self.swap_poll_every = max(1, swap_poll_every)
        self.version = getattr(param_source, "version", 0) or 0
        self._mrope = cfg.rope == "mrope"

        mesh = mesh or make_host_mesh(1, 1)
        pol = pol or ShardingPolicy(
            dp_axes=("data",), dp_sizes=(1,), model_axis_size=1, fsdp=False
        )
        prefill_step = make_prefill_step(cfg, mesh, pol, max_len=max_len)
        decode_step = make_decode_step(cfg, mesh, pol, return_logits=False)

        def prefill_tok(params, batch):
            logits, cache = prefill_step(params, batch)
            tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            return tok[:, None], cache

        # one trace per distinct prompt length (jit's shape cache)
        self._prefill = jax.jit(prefill_tok)

        mrope = self._mrope

        def tick(params, tokens, positions, cache):
            mp = (
                jnp.broadcast_to(
                    positions[None, :, None], (3, positions.shape[0], 1)
                )
                if mrope else None
            )
            next_tok, new_cache = decode_step(params, tokens, positions, cache, mp)
            return next_tok, positions + 1, new_cache

        # positions/cache donated: the step rewrites the KV cache in place.
        # The token vector is NOT donated — the previous tick's tokens are
        # still held by the deferred-fetch queue.
        self._tick = jax.jit(tick, donate_argnums=(2, 3))

        def insert(cache, tokens, positions, slot_cache, first_tok, pos0, b):
            cache = insert_slot_cache(cache, slot_cache, b)
            tokens = jax.lax.dynamic_update_slice(tokens, first_tok, (b, jnp.int32(0)))
            positions = jax.lax.dynamic_update_slice(positions, pos0[None], (b,))
            return tokens, positions, cache

        self._insert = jax.jit(insert, donate_argnums=(0, 2))

    # ------------------------------------------------------------------
    def _make_prompt_batch(self, prompt: np.ndarray) -> Batch:
        S = int(prompt.shape[0])
        toks = jnp.asarray(prompt, jnp.int32)[None]
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (1, S))
        batch = Batch(tokens=toks, positions=pos)
        if self._mrope:
            batch = batch._replace(
                positions=jnp.broadcast_to(
                    jnp.arange(S, dtype=jnp.int32)[None, None], (3, 1, S)
                ),
                embeds=jnp.zeros((1, S, self.cfg.d_model),
                                 jnp.dtype(self.cfg.dtype)),
                embed_mask=jnp.zeros((1, S), bool),
            )
        return batch

    def _fresh_state(self):
        tokens = jnp.zeros((self.num_slots, 1), jnp.int32)
        positions = jnp.zeros((self.num_slots,), jnp.int32)
        cache = init_cache(self.cfg, self.num_slots, self.max_len,
                           jnp.dtype(self.cfg.dtype))
        return tokens, positions, cache

    def warmup(self, prompt_lens: Sequence[int]) -> None:
        """Compile every hot-path trace (per-bucket prefill, insert, tick)
        outside the timed window."""
        tokens, positions, cache = self._fresh_state()
        b = jnp.asarray(0, jnp.int32)
        for S in sorted(set(int(s) for s in prompt_lens)):
            batch = self._make_prompt_batch(np.zeros((S,), np.int32))
            tok, slot_cache = self._prefill(self.params, batch)
            tokens, positions, cache = self._insert(
                cache, tokens, positions, slot_cache, tok,
                jnp.asarray(S, jnp.int32), b,
            )
        tokens, positions, cache = self._tick(
            self.params, tokens, positions, cache
        )
        jax.block_until_ready(tokens)

    # ------------------------------------------------------------------
    def _poll_swap(self, tick_idx: int, clock, swaps: List[dict]) -> None:
        if self.source is None:
            return
        got = self.source.poll()
        if got is None:
            return
        ver, new_params = got
        # cast onto the serving dtype layout; structure must match, which a
        # chain model block / checkpoint of the same arch guarantees
        self.params = jax.tree.map(
            lambda n, o: jnp.asarray(n, o.dtype), new_params, self.params
        )
        self.version = ver
        swaps.append({"round": int(ver), "tick": tick_idx,
                      "t": round(clock.now(), 6)})

    def _drain(self, pending: Deque[_Pending],
               results: Dict[int, RequestResult], clock,
               force: bool = False) -> None:
        """Fetch token vectors one tick late: the block on ``np.asarray``
        overlaps with the next tick already running on device."""
        while pending and (force or len(pending) > 1):
            rec = pending.popleft()
            toks = np.asarray(rec.tok)
            now = clock.now()
            for rid, row, first, last in rec.deliveries:
                r = results[rid]
                r.tokens.append(int(toks[row, 0]))
                if first:
                    r.first_token = now
                if last:
                    r.finished = now
                    r.version_finished = rec.version

    # ------------------------------------------------------------------
    def run(
        self,
        requests: Sequence[Request],
        *,
        policy: str = "continuous",
        clock=None,
        on_tick: Optional[Callable[[int], None]] = None,
    ) -> ServeReport:
        """Serve a trace to completion and return the per-request results.

        ``on_tick(tick_idx)`` fires at every tick boundary — the benchmark
        uses it to commit a new model block to the watched chain mid-trace.
        """
        for r in requests:
            if r.max_new < 1:
                raise ValueError(f"request {r.rid}: max_new must be >= 1")
            if r.prompt_len < 1:
                raise ValueError(f"request {r.rid}: empty prompt")
            if r.prompt_len + r.max_new - 1 > self.max_len:
                raise ValueError(
                    f"request {r.rid}: prompt {r.prompt_len} + gen {r.max_new}"
                    f" exceeds max_len {self.max_len}"
                )

        clock = clock or WallClock()
        sched = FifoScheduler(requests, policy=policy)
        table = SlotTable(self.num_slots)
        tokens, positions, cache = self._fresh_state()
        results: Dict[int, RequestResult] = {
            r.rid: RequestResult(rid=r.rid, prompt_len=r.prompt_len,
                                 max_new=r.max_new, arrival=r.arrival)
            for r in requests
        }
        pending: Deque[_Pending] = deque()
        swaps: List[dict] = []
        tick_idx = 0
        active_ticks = 0          # sum of active slots over all ticks
        t_start = time.perf_counter()

        while not (sched.exhausted and table.all_free and not pending):
            if tick_idx % self.swap_poll_every == 0:
                self._poll_swap(tick_idx, clock, swaps)

            # ---- admissions (prefill-into-slot) --------------------------
            for b, req in sched.admissions(table, clock.now()):
                res = results[req.rid]
                res.admitted = clock.now()
                res.version_admitted = self.version
                batch = self._make_prompt_batch(req.prompt)
                tok, slot_cache = self._prefill(self.params, batch)
                one_shot = req.max_new == 1
                pending.append(_Pending(
                    tok=tok,
                    deliveries=[(req.rid, 0, True, one_shot)],
                    version=self.version,
                ))
                if not one_shot:
                    tokens, positions, cache = self._insert(
                        cache, tokens, positions, slot_cache, tok,
                        jnp.asarray(req.prompt_len, jnp.int32),
                        jnp.asarray(b, jnp.int32),
                    )
                    table.occupy(b, req.rid, req.max_new - 1)

            # ---- one fused decode tick over the whole slot batch ---------
            if table.num_active:
                rids = table.active_snapshot()
                tokens, positions, cache = self._tick(
                    self.params, tokens, positions, cache
                )
                done_slots = table.decrement_active()
                done_set = set(done_slots)
                deliveries = [
                    (int(rids[b]), b, False, b in done_set)
                    for b in range(self.num_slots)
                    if rids[b] >= 0
                ]
                pending.append(_Pending(tok=tokens, deliveries=deliveries,
                                        version=self.version))
                for b in done_slots:
                    table.release(b)
                active_ticks += len(deliveries)
                tick_idx += 1
                clock.tick()
                if on_tick is not None:
                    on_tick(tick_idx)
                self._drain(pending, results, clock)
            else:
                # idle: nothing decoding — drain stragglers, jump to the
                # next arrival
                self._drain(pending, results, clock, force=True)
                na = sched.next_arrival()
                if na is not None:
                    clock.advance_to(na)

        self._drain(pending, results, clock, force=True)
        wall = time.perf_counter() - t_start
        occupancy = (active_ticks / (tick_idx * self.num_slots)
                     if tick_idx else 0.0)
        ordered = [results[r.rid] for r in sorted(requests, key=lambda q: q.rid)]
        return ServeReport(results=ordered, wall_s=wall, ticks=tick_idx,
                           occupancy=occupancy, swaps=swaps, policy=policy)
