"""Synthetic heavy-traffic traces + latency/throughput metric aggregation.

The driver models the BFLC deployment story: a large user population hits a
serving node with Poisson arrivals and mixed prompt/generation lengths.
Metrics follow the standard serving vocabulary — tokens/s, TTFT (arrival to
first generated token) and end-to-end request latency, p50/p99 over the
request population — and land in ``BENCH_serve.json`` rows.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serve.slots import Request, RequestResult


def make_poisson_trace(
    *,
    num_requests: int,
    rate: float,
    prompt_lens: Sequence[int],
    gen_lens: Sequence[int],
    vocab_size: int,
    seed: int = 0,
) -> List[Request]:
    """Poisson arrival process (exponential inter-arrival at ``rate`` req/s)
    with prompt/generation lengths drawn uniformly from the given buckets.

    Lengths come from a small bucket set on purpose: the engine prefills at
    exact prompt lengths (one XLA trace per distinct length, cached), which
    keeps admission correct for every mixer kind — ring-buffer SWA and
    recurrent (mamba/rwkv) caches included — without pad-token masking."""
    if rate <= 0:
        raise ValueError("rate must be positive")
    rng = np.random.default_rng(seed)
    t = 0.0
    reqs: List[Request] = []
    for rid in range(num_requests):
        t += float(rng.exponential(1.0 / rate))
        s = int(rng.choice(np.asarray(prompt_lens)))
        g = int(rng.choice(np.asarray(gen_lens)))
        prompt = rng.integers(0, vocab_size, (s,), dtype=np.int64).astype(np.int32)
        reqs.append(Request(rid=rid, prompt=prompt, max_new=g, arrival=t))
    return reqs


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


def aggregate(
    results: Sequence[RequestResult],
    *,
    wall_s: float,
    ticks: int,
    occupancy: float,
    swaps: int = 0,
) -> Dict[str, float]:
    """One BENCH_serve.json row from a finished run."""
    gen = sum(len(r.tokens) for r in results)
    ttft = [r.first_token - r.arrival for r in results if r.first_token >= 0]
    lat = [r.finished - r.arrival for r in results if r.finished >= 0]
    return {
        "requests": len(results),
        "generated_tokens": gen,
        "wall_s": round(wall_s, 4),
        "tok_s": round(gen / wall_s, 2) if wall_s > 0 else 0.0,
        "ticks": ticks,
        "occupancy": round(occupancy, 4),
        "ttft_p50_ms": round(_pct(ttft, 50) * 1e3, 2),
        "ttft_p99_ms": round(_pct(ttft, 99) * 1e3, 2),
        "latency_p50_ms": round(_pct(lat, 50) * 1e3, 2),
        "latency_p99_ms": round(_pct(lat, 99) * 1e3, 2),
        "swaps": swaps,
    }
