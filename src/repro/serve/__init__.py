"""Continuous-batching inference serving off the latest chain model."""
from repro.serve.engine import ServeEngine, ServeReport, VirtualClock, WallClock
from repro.serve.params import ChainParamSource, CheckpointParamSource, checkpoint_name
from repro.serve.scheduler import FifoScheduler
from repro.serve.slots import Request, RequestResult, SlotTable
from repro.serve.trace import aggregate, make_poisson_trace

__all__ = [
    "ChainParamSource",
    "CheckpointParamSource",
    "FifoScheduler",
    "Request",
    "RequestResult",
    "ServeEngine",
    "ServeReport",
    "SlotTable",
    "VirtualClock",
    "WallClock",
    "aggregate",
    "checkpoint_name",
    "make_poisson_trace",
]
