"""Hot-swap parameter sources: where the serving engine gets fresh weights.

BFLC stores the global model on-chain (paper §III.A), so a serving node can
always read the latest committee-approved parameters.  The engine polls a
``ParamSource`` at tick boundaries and swaps the whole parameter pytree in
one reference assignment — in-flight requests keep their KV caches and
continue decoding under the new weights (no drain, no drop).

Two sources:

* ``ChainParamSource``      — watches a live ``repro.core.blockchain.Chain``
  (the in-process round loop commits model blocks as training progresses).
* ``CheckpointParamSource`` — watches a directory for
  ``model_round_<t>.msgpack`` snapshots written via ``repro.checkpoint``
  (a serving node separate from the trainer).  Snapshots may hold the raw
  f32 pytree or an int8-codec chain blob; blobs are decoded through the
  chain's ``Int8UpdateCodec``.
"""
from __future__ import annotations

import os
import re
from typing import Any, Optional, Tuple

from repro.checkpoint import load_model_payload

CKPT_RE = re.compile(r"^model_round_(\d+)\.msgpack$")


def checkpoint_name(round_t: int) -> str:
    return f"model_round_{round_t}.msgpack"


class ChainParamSource:
    """Poll a live chain for a newer model block (O(1) latest-model read)."""

    def __init__(self, chain):
        self.chain = chain
        self._seen = chain.current_round

    def poll(self) -> Optional[Tuple[int, Any]]:
        r = self.chain.current_round
        if r <= self._seen:
            return None
        self._seen = r
        round_t, model = self.chain.latest_model()
        return round_t, model

    @property
    def version(self) -> int:
        return self._seen


class CheckpointParamSource:
    """Poll a snapshot directory for a newer ``model_round_<t>.msgpack``."""

    def __init__(self, directory: str, codec=None, start_round: int = -1):
        self.directory = directory
        self.codec = codec
        self._seen = start_round

    def _latest_on_disk(self) -> Optional[int]:
        try:
            names = os.listdir(self.directory)
        except FileNotFoundError:
            return None
        rounds = [int(m.group(1)) for n in names if (m := CKPT_RE.match(n))]
        return max(rounds) if rounds else None

    def poll(self) -> Optional[Tuple[int, Any]]:
        latest = self._latest_on_disk()
        if latest is None or latest <= self._seen:
            return None
        self._seen = latest
        path = os.path.join(self.directory, checkpoint_name(latest))
        return latest, load_model_payload(path, codec=self.codec)

    @property
    def version(self) -> int:
        return self._seen
