"""Request / slot bookkeeping for the continuous-batching engine.

The device side of serving is a fixed-capacity batch of ``num_slots``
request *slots* (one row of the batched KV cache + token/position vectors).
This module is the host-side mirror: which request occupies which slot, how
many tokens it still owes, and the per-request timing record the benchmark
aggregates.  All of it is plain numpy/python — the engine keeps device and
host state in sync at tick boundaries.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

FREE = -1


@dataclass
class Request:
    """One serving request: a prompt and a generation budget."""

    rid: int
    prompt: np.ndarray          # (S,) int32 token ids
    max_new: int                # tokens to generate (>= 1; the first comes
                                # from prefill itself)
    arrival: float = 0.0        # seconds since trace start

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])


@dataclass
class RequestResult:
    """Completed request: generated ids + the latency-metric timestamps."""

    rid: int
    prompt_len: int
    max_new: int
    tokens: List[int] = field(default_factory=list)
    arrival: float = 0.0
    admitted: float = -1.0      # entered a slot (prefill launched)
    first_token: float = -1.0   # first generated token observed
    finished: float = -1.0      # last generated token observed
    # params version (e.g. chain round) active when the request was admitted
    # and when it finished — differing values mean the request spanned a
    # hot-swap
    version_admitted: int = -1
    version_finished: int = -1

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.max_new

    @property
    def spans_swap(self) -> bool:
        return self.version_admitted != self.version_finished


class SlotTable:
    """Host mirror of the decode batch: per-slot request id + tokens owed."""

    def __init__(self, num_slots: int):
        if num_slots < 1:
            raise ValueError("need at least one slot")
        self.num_slots = num_slots
        self.rid = np.full((num_slots,), FREE, np.int64)
        self.remaining = np.zeros((num_slots,), np.int64)

    # ------------------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [int(b) for b in np.nonzero(self.rid == FREE)[0]]

    @property
    def num_active(self) -> int:
        return int(np.sum(self.rid != FREE))

    @property
    def all_free(self) -> bool:
        return self.num_active == 0

    def occupy(self, b: int, rid: int, remaining: int) -> None:
        if self.rid[b] != FREE:
            raise RuntimeError(f"slot {b} already holds request {self.rid[b]}")
        self.rid[b] = rid
        self.remaining[b] = remaining

    def release(self, b: int) -> None:
        self.rid[b] = FREE
        self.remaining[b] = 0

    def active_snapshot(self) -> np.ndarray:
        """Slot -> rid copy, captured at tick launch (admissions between
        ticks re-assign slots, so the drain path must use the launch-time
        mapping, not the live table)."""
        return self.rid.copy()

    def decrement_active(self) -> List[int]:
        """One decode tick happened: every active slot owes one token fewer.
        Returns the slots that just produced their final token (freed by the
        caller after recording)."""
        done = []
        for b in range(self.num_slots):
            if self.rid[b] == FREE:
                continue
            self.remaining[b] -= 1
            if self.remaining[b] <= 0:
                done.append(b)
        return done
