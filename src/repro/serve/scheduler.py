"""Admission scheduling: which queued requests enter which free slots.

Two policies over one FIFO arrival queue:

* ``continuous`` — in-flight batching: any free slot is filled as soon as an
  arrived request is waiting.  Finished slots free at tick boundaries, so a
  short request never waits for a long one to drain.
* ``static``     — the legacy static-batch discipline (the baseline the
  benchmark compares against): requests are only admitted when *every* slot
  is free, i.e. the whole batch starts together and the next batch waits for
  the slowest request of the current one.

Both see the same arrival trace and the same engine; the measured gap is
purely the admission policy.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Iterable, List, Optional, Tuple

from repro.serve.slots import Request, SlotTable

POLICIES = ("continuous", "static")


class FifoScheduler:
    def __init__(self, requests: Iterable[Request], policy: str = "continuous"):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; known: {POLICIES}")
        self.policy = policy
        # arrival order; the trace generator emits sorted arrivals
        self._future: Deque[Request] = deque(
            sorted(requests, key=lambda r: (r.arrival, r.rid))
        )
        self._queue: Deque[Request] = deque()

    # ------------------------------------------------------------------
    def ingest(self, now: float) -> None:
        """Move requests whose arrival time has passed into the ready queue."""
        while self._future and self._future[0].arrival <= now:
            self._queue.append(self._future.popleft())

    @property
    def queued(self) -> int:
        return len(self._queue)

    @property
    def exhausted(self) -> bool:
        """No request is waiting now and none will ever arrive."""
        return not self._future and not self._queue

    def next_arrival(self) -> Optional[float]:
        return self._future[0].arrival if self._future else None

    # ------------------------------------------------------------------
    def admissions(self, table: SlotTable, now: float) -> List[Tuple[int, Request]]:
        """(slot, request) pairs to admit at this tick boundary."""
        self.ingest(now)
        if not self._queue:
            return []
        if self.policy == "static" and not table.all_free:
            # batch barrier: the whole cohort drains before the next starts
            return []
        out: List[Tuple[int, Request]] = []
        for b in table.free_slots():
            if not self._queue:
                break
            out.append((b, self._queue.popleft()))
        return out
