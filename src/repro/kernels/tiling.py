"""Single source of the kernels' lane tiling.

Every kernel (and the jnp oracles) tiles the flattened update dimension in
BLOCK_D-lane chunks, and the quantization codec stores one scale per
BLOCK_D tile — so the constant must agree across modules or fused kernels
would apply scales computed over a different window.  Tune it here only.

2048 = 16 x 128: lane-aligned for the VPU, and a whole (K<=64, BLOCK_D)
f32 tile fits comfortably in VMEM.
"""
BLOCK_D = 2048
