"""jit'd public wrappers around the Pallas kernels.

This is the method-dispatch layer of the aggregation engine: callers hand
over a f32 ``(K, D)`` stack *or* the chain's quantized representation
(int8 stack + per-tile scales) and name a reduction; padding to the tile
boundary happens exactly once here, the interpret flag is picked off-TPU,
and pytree-level helpers adapt model updates.

  aggregate(stack, method=..., weights=..., trim=...)        f32 path
  aggregate_quantized(q, scales, method=..., ...)            fused int8 path
  quantize_stack(stack)                                      round codec

The sharded multi-device engine builds its programs once through the
factories at the bottom (``make_quantize_stack_sharded`` /
``make_aggregate_quantized_sharded``): each device runs the same Pallas
kernels on its D-shard of the int8 stack — tile-aligned by construction,
so per-shard results are bitwise identical to the single-device tiles.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.cwmed import cwmed_kernel, trimmed_mean_kernel
from repro.kernels.fedavg_agg import fedavg_agg_kernel
from repro.kernels.tiling import BLOCK_D
from repro.kernels.fused_agg import METHODS, fused_agg_kernel
from repro.kernels.quantize import (
    dequantize_kernel,
    quantize_kernel,
    quantize_stack_kernel,
)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def padded_dim(d: int) -> int:
    """Smallest multiple of BLOCK_D >= d."""
    return d + (-d) % BLOCK_D


def _pad_to_block(x: jnp.ndarray, axis: int = -1) -> Tuple[jnp.ndarray, int]:
    d = x.shape[axis]
    pad = (-d) % BLOCK_D
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def _normalize_weights(K: int, weights: Optional[jnp.ndarray]) -> jnp.ndarray:
    from repro.core.aggregation import normalize_weights

    return normalize_weights(K, weights)


# ----------------------------------------------------------------------
# method dispatch: f32 stacks
# ----------------------------------------------------------------------
def aggregate(
    stack: jnp.ndarray,
    method: str = "fedavg",
    weights: Optional[jnp.ndarray] = None,
    trim: int = 1,
) -> jnp.ndarray:
    """(K, D) f32 -> (D,) via the Pallas kernels; pads once, dispatches.

    fedavg weights may be unnormalized (e.g. raw committee scores) — they
    are normalized to sum 1 here; use ``fedavg_agg`` for a raw weighted
    sum."""
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r} (want one of {METHODS})")
    K, D = stack.shape
    if method == "fedavg":
        return fedavg_agg(stack, _normalize_weights(K, weights))
    # zero-pad to the tile boundary: reductions are per-lane, so padded
    # lanes only affect padded outputs, which are sliced off below
    padded, _ = _pad_to_block(stack)
    if method == "cwmed":
        out = cwmed_kernel(padded, interpret=_interpret())
    else:
        out = trimmed_mean_kernel(padded, trim=trim, interpret=_interpret())
    return out[:D]


def fedavg_agg(stack: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """(K, D) x (K,) -> (D,) weighted SUM via the Pallas kernel — weights
    are used as-is (callers own normalization)."""
    D = stack.shape[1]
    padded, _ = _pad_to_block(stack)
    out = fedavg_agg_kernel(
        padded, jnp.asarray(weights).astype(jnp.float32),
        interpret=_interpret(),
    )
    return out[:D]


def cwmed(stack: jnp.ndarray) -> jnp.ndarray:
    """(K, D) -> (D,) coordinate-wise median via the Pallas kernel."""
    return aggregate(stack, "cwmed")


def trimmed_mean(stack: jnp.ndarray, trim: int = 1) -> jnp.ndarray:
    """(K, D) -> (D,) coordinate-wise trimmed mean via the Pallas kernel."""
    return aggregate(stack, "trimmed_mean", trim=trim)


# ----------------------------------------------------------------------
# quantized representation: codec + fused one-pass aggregation
# ----------------------------------------------------------------------
def quantize(x: jnp.ndarray):
    """(D,) -> (q int8 (D,), scales, D) — chain-storage codec."""
    D = x.shape[0]
    if D == 0:  # zero-size pytrees: nothing to tile, nothing to store
        return jnp.zeros((0,), jnp.int8), jnp.zeros((0,), jnp.float32), 0
    padded, _ = _pad_to_block(x)
    q, s = quantize_kernel(padded, interpret=_interpret())
    return q, s, D


def dequantize(q: jnp.ndarray, scales: jnp.ndarray, D: int) -> jnp.ndarray:
    if D == 0:
        return jnp.zeros((0,), jnp.float32)
    out = dequantize_kernel(q, scales, interpret=_interpret())
    return out[:D]


def quantize_stack(stack: jnp.ndarray):
    """(K, D) f32 -> (q (K, Dpad) int8, scales (K, nblk) f32, D).

    One kernel launch quantizes a whole round's K update vectors; zero-pads
    to the tile boundary (padded lanes quantize to 0 and are never read back
    past D)."""
    K, D = stack.shape
    if D == 0:
        return jnp.zeros((K, 0), jnp.int8), jnp.zeros((K, 0), jnp.float32), 0
    padded, _ = _pad_to_block(stack)
    q, s = quantize_stack_kernel(padded, interpret=_interpret())
    return q, s, D


def aggregate_quantized(
    q: jnp.ndarray,
    scales: jnp.ndarray,
    D: Optional[int] = None,
    method: str = "fedavg",
    weights: Optional[jnp.ndarray] = None,
    trim: int = 1,
    quantize_out: bool = False,
):
    """Fused one-pass aggregation straight from the chain's int8 blocks.

    q: (K, Dpad) int8, scales: (K, Dpad // BLOCK_D) f32, D: true (unpadded)
    dimension.  Returns (D,) f32 — or, with ``quantize_out``, the quantized
    result ``(q_out (Dpad,) int8, out_scales, D)`` ready for chain storage —
    without ever materializing the f32 (K, D) stack in HBM."""
    K, Dpad = q.shape
    true_d = Dpad if D is None else D
    w = _normalize_weights(K, weights)
    out = fused_agg_kernel(
        q, scales, w, method=method, trim=trim,
        quantize_out=quantize_out, interpret=_interpret(),
    )
    if quantize_out:
        q_out, s_out = out
        return q_out, s_out, true_d
    return out[:true_d]


def candidates_from_quantized(
    base: jnp.ndarray,
    q: jnp.ndarray,
    scales: jnp.ndarray,
    D: Optional[int] = None,
) -> jnp.ndarray:
    """Fused candidate rebuild straight from the chain's int8 blocks.

    base: (D,) f32 global params; q: (K, Dpad) int8 update rows; scales:
    (K, Dpad // BLOCK_D) f32.  Returns the (K, D) f32 candidate stack
    ``base + dequant(q_k)`` — one int8 read of the stack, dequantized
    in-register with the delta applied during the base-parameter load, so
    the f32 update stack is never materialized (the validation-side mirror
    of ``aggregate_quantized``)."""
    from repro.kernels.fused_score import fused_candidates_kernel

    K, Dpad = q.shape
    true_d = Dpad if D is None else D
    padded, _ = _pad_to_block(base.astype(jnp.float32))
    out = fused_candidates_kernel(padded, q, scales, interpret=_interpret())
    return out[:, :true_d]


# ----------------------------------------------------------------------
# sharded multi-device engine (one program per mesh, built once)
# ----------------------------------------------------------------------
def padded_dim_sharded(d: int, shards: int) -> int:
    """Smallest multiple of ``shards * BLOCK_D`` >= d.

    Padding to this boundary keeps every D-shard tile-aligned, so the
    per-shard quantization tiles (and their scales) coincide exactly with
    the single-device tiles — the sharded codec differs from the
    single-device codec only in how many all-zero padding tiles trail the
    data."""
    chunk = BLOCK_D * shards
    return d + (-d) % chunk


def make_quantize_stack_sharded(mesh, axis: str = "data"):
    """Sharding-aware round codec: jitted ``(K, D) f32 -> (q, scales)``.

    Pads D to ``padded_dim_sharded(D, ndev)`` and shard_maps
    ``quantize_stack_kernel`` over the mesh's data axis — each device
    quantizes its own (K, Dpad/ndev) slice of the stack, one kernel launch
    per device, no cross-device traffic (tiles are independent)."""
    from jax.sharding import PartitionSpec as P

    from repro.shard_compat import shard_map

    ndev = mesh.shape[axis]
    interpret = _interpret()

    def _shard(chunk):                         # (K, Dpad / ndev) per device
        return quantize_stack_kernel(chunk, interpret=interpret)

    sharded = shard_map(_shard, mesh=mesh, in_specs=P(None, axis),
                        out_specs=(P(None, axis), P(None, axis)))

    @jax.jit
    def quantize_sharded(stack: jnp.ndarray):
        D = stack.shape[1]
        pad = padded_dim_sharded(D, ndev) - D
        if pad:
            stack = jnp.pad(stack, ((0, 0), (0, pad)))
        return sharded(stack)

    return quantize_sharded


def make_aggregate_quantized_sharded(mesh, axis: str = "data",
                                     method: str = "fedavg", trim: int = 1):
    """Sharded fused aggregation: jitted ``(q, scales, weights) -> (Dpad,)``.

    Each device runs the fused int8->dequant->reduce kernel on its D-shard
    of the stack (the ROADMAP follow-up); the (Dpad,)-sharded result is
    all-gathered into the replicated model block by XLA at the first
    replicated use (``apply_update``).  ``weights`` must already be
    normalized (``normalize_weights``) and is replicated to every shard so
    the fedavg reduction weighs rows identically everywhere."""
    from jax.sharding import PartitionSpec as P

    from repro.kernels.fused_agg import make_fused_agg_fn
    from repro.shard_compat import shard_map

    fused = make_fused_agg_fn(method=method, trim=trim,
                              interpret=_interpret())
    sharded = shard_map(fused, mesh=mesh,
                        in_specs=(P(None, axis), P(None, axis), P()),
                        out_specs=P(axis))

    @jax.jit
    def aggregate_sharded(q: jnp.ndarray, scales: jnp.ndarray,
                          weights: jnp.ndarray):
        return sharded(q, scales, weights.astype(jnp.float32))

    return aggregate_sharded


# ----------------------------------------------------------------------
# pytree adapters
# ----------------------------------------------------------------------
def quantize_pytree(tree):
    """Flatten + quantize a model/update pytree for on-chain storage."""
    from jax.flatten_util import ravel_pytree

    flat, unravel = ravel_pytree(tree)
    q, s, D = quantize(flat.astype(jnp.float32))
    return {"q": q, "scales": s, "d": D}, unravel


def dequantize_pytree(blob, unravel):
    return unravel(dequantize(blob["q"], blob["scales"], blob["d"]))


class Int8UpdateCodec:
    """Chain payload codec: update pytree <-> int8 blob dict.

    The unravel structure is fixed at construction from an example pytree
    (all BFLC updates share the model's structure), so decode needs no
    side-channel."""

    def __init__(self, example_pytree):
        from jax.flatten_util import ravel_pytree

        flat, self._unravel = ravel_pytree(example_pytree)
        self.dim = flat.shape[0]

    def encode(self, tree):
        blob, _ = quantize_pytree(tree)
        return blob

    def decode(self, blob):
        return dequantize_pytree(blob, self._unravel)

    def unravel(self, flat: jnp.ndarray):
        return self._unravel(flat)
