"""jit'd public wrappers around the Pallas kernels: pad to tile boundaries,
pick interpret mode off-TPU, and expose pytree-level helpers.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.cwmed import cwmed_kernel
from repro.kernels.fedavg_agg import BLOCK_D, fedavg_agg_kernel
from repro.kernels.quantize import dequantize_kernel, quantize_kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to_block(x: jnp.ndarray, axis: int = -1) -> Tuple[jnp.ndarray, int]:
    d = x.shape[axis]
    pad = (-d) % BLOCK_D
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def fedavg_agg(stack: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """(K, D) x (K,) -> (D,) weighted sum via the Pallas kernel."""
    D = stack.shape[1]
    padded, _ = _pad_to_block(stack)
    out = fedavg_agg_kernel(padded, weights, interpret=_interpret())
    return out[:D]


def cwmed(stack: jnp.ndarray) -> jnp.ndarray:
    """(K, D) -> (D,) coordinate-wise median via the Pallas kernel."""
    D = stack.shape[1]
    # pad with +inf/-inf in equal halves would bias the median; instead pad
    # with the first row's values so padded lanes stay valid and are sliced off
    pad = (-D) % BLOCK_D
    if pad:
        fill = jnp.broadcast_to(stack[:, :1], (stack.shape[0], pad))
        stack = jnp.concatenate([stack, fill], axis=1)
    out = cwmed_kernel(stack, interpret=_interpret())
    return out[:D]


def quantize(x: jnp.ndarray):
    """(D,) -> (q int8 (D,), scales, D) — chain-storage codec."""
    D = x.shape[0]
    padded, _ = _pad_to_block(x)
    q, s = quantize_kernel(padded, interpret=_interpret())
    return q, s, D


def dequantize(q: jnp.ndarray, scales: jnp.ndarray, D: int) -> jnp.ndarray:
    out = dequantize_kernel(q, scales, interpret=_interpret())
    return out[:D]


def quantize_pytree(tree):
    """Flatten + quantize a model/update pytree for on-chain storage."""
    from jax.flatten_util import ravel_pytree

    flat, unravel = ravel_pytree(tree)
    q, s, D = quantize(flat.astype(jnp.float32))
    return {"q": q, "scales": s, "d": D}, unravel


def dequantize_pytree(blob, unravel):
    return unravel(dequantize(blob["q"], blob["scales"], blob["d"]))
