"""Pallas TPU kernel: fused quantized aggregation — the BFLC round hot path
in ONE grid pass.

The staged pipeline (dequantize K rows -> materialize the full (K, D) f32
stack in HBM -> fedavg/cwmed kernel -> quantize the result) costs ~3 f32
passes over K*D elements.  At chain-stored int8 precision that is pure
waste: this kernel streams K int8 update tiles plus their per-tile scales
into VMEM, dequantizes **in-register**, reduces (weighted fedavg, coordinate
-wise median, or trimmed mean via the shared odd-even network), and — when
the result goes straight back onto the chain — re-quantizes the output tile
in the same grid step.

HBM traffic per grid step (tile of BLOCK_D lanes, K committee updates):

  staged:  K*B int8 read + K*B f32 write (dequant)
           + K*B f32 read + B f32 write (aggregate)
           + B f32 read + B int8 write  (quant)        ~= 9*K*B bytes total
  fused:   K*B int8 read + B write (f32 or int8)       ~=   K*B bytes total

i.e. one int8 read of the stack + one write of the result — ~12x fewer
bytes on the dominant read than the f32 staged path the runtime used to
run.  Scales ride along in the same pass: (K, 1) f32 per tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.cwmed import (
    median_of_sorted,
    sort_rows,
    trimmed_mean_of_sorted,
)
from repro.kernels.tiling import BLOCK_D

METHODS = ("fedavg", "cwmed", "trimmed_mean")


def _reduce_tile(w, s, x, *, method: str, trim: int) -> jnp.ndarray:
    """Dequantize a (K, BLOCK_D) int8 tile in-register and reduce to (BLOCK_D,)."""
    K = x.shape[0]
    rows_f = x.astype(jnp.float32) * s          # (K, BLOCK_D): deq in-register
    if method == "fedavg":
        return jnp.sum(rows_f * w, axis=0)
    rows = sort_rows([rows_f[k, :] for k in range(K)])
    if method == "cwmed":
        return median_of_sorted(rows)
    return trimmed_mean_of_sorted(rows, trim)


def _fused_kernel(w_ref, s_ref, x_ref, o_ref, *, method: str, trim: int):
    # x_ref (K, BLOCK_D) int8; s_ref (K, 1) f32 scales; w_ref (K, 1) weights
    o_ref[0, :] = _reduce_tile(
        w_ref[...], s_ref[...], x_ref[...], method=method, trim=trim
    )


def _fused_kernel_qout(w_ref, s_ref, x_ref, q_ref, so_ref, *,
                       method: str, trim: int):
    agg = _reduce_tile(
        w_ref[...], s_ref[...], x_ref[...], method=method, trim=trim
    )
    amax = jnp.max(jnp.abs(agg))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q_ref[0, :] = jnp.clip(jnp.round(agg / scale), -127, 127).astype(jnp.int8)
    so_ref[0, 0] = scale


def make_fused_agg_fn(*, method: str = "fedavg", trim: int = 1,
                      quantize_out: bool = False, interpret: bool = True):
    """Unjitted ``(qstack, scales, weights) -> out`` closure over the static
    kernel knobs — the form the sharded aggregation path composes under
    ``shard_map`` (each device invokes it on its D-shard of the stack;
    ``fused_agg_kernel`` below is the same closure jitted for direct use)."""
    return functools.partial(
        _fused_agg, method=method, trim=trim,
        quantize_out=quantize_out, interpret=interpret,
    )


def _fused_agg(
    qstack: jnp.ndarray,
    scales: jnp.ndarray,
    weights: jnp.ndarray,
    *,
    method: str = "fedavg",
    trim: int = 1,
    quantize_out: bool = False,
    interpret: bool = True,
):
    K, D = qstack.shape
    assert D % BLOCK_D == 0, D
    assert qstack.dtype == jnp.int8, qstack.dtype
    nblk = D // BLOCK_D
    assert scales.shape == (K, nblk), (scales.shape, K, nblk)
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}")
    if method == "trimmed_mean" and not 0 <= 2 * trim < K:
        raise ValueError(f"trim={trim} too large for K={K}")

    in_specs = [
        pl.BlockSpec((K, 1), lambda i: (0, 0)),          # weights
        pl.BlockSpec((K, 1), lambda i: (0, i)),          # this tile's scales
        pl.BlockSpec((K, BLOCK_D), lambda i: (0, i)),    # int8 tile
    ]
    operands = (weights.reshape(K, 1).astype(jnp.float32), scales, qstack)
    if not quantize_out:
        out = pl.pallas_call(
            functools.partial(_fused_kernel, method=method, trim=trim),
            grid=(nblk,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, BLOCK_D), lambda i: (0, i)),
            out_shape=jax.ShapeDtypeStruct((1, D), jnp.float32),
            interpret=interpret,
        )(*operands)
        return out[0]
    q, s = pl.pallas_call(
        functools.partial(_fused_kernel_qout, method=method, trim=trim),
        grid=(nblk,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, BLOCK_D), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, D), jnp.int8),
            jax.ShapeDtypeStruct((1, nblk), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)
    return q[0], s[0]


@functools.partial(
    jax.jit, static_argnames=("method", "trim", "quantize_out", "interpret")
)
def fused_agg_kernel(
    qstack: jnp.ndarray,
    scales: jnp.ndarray,
    weights: jnp.ndarray,
    *,
    method: str = "fedavg",
    trim: int = 1,
    quantize_out: bool = False,
    interpret: bool = True,
):
    """qstack: (K, D) int8; scales: (K, D // BLOCK_D) f32; weights: (K,)
    normalized (ignored unless method == "fedavg").

    Returns (D,) f32, or (q (D,) int8, out_scales (D // BLOCK_D,) f32) when
    ``quantize_out`` — everything in a single grid pass over the stack."""
    return _fused_agg(qstack, scales, weights, method=method, trim=trim,
                      quantize_out=quantize_out, interpret=interpret)
