"""Pallas TPU kernel: weighted FedAvg aggregation of K client updates.

The per-round aggregation is BFLC's compute hot spot at scale: K flattened
update vectors (K, D) with D = model size (10^7..10^11) reduced to (D,) with
committee-score weights.  The reduction is memory-bound; the kernel streams
(K, BLOCK_D) tiles through VMEM and emits one (BLOCK_D,) tile per grid step,
so HBM traffic is exactly one read of the stack + one write of the result.

Tiling: BLOCK_D = 2048 lanes (16 x 128 — lane-aligned for the VPU); the full
K (committee k is small, <= 64 in practice) fits the sublane dim of one tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import BLOCK_D


def _fedavg_kernel(w_ref, x_ref, o_ref):
    # x_ref: (K, BLOCK_D) VMEM tile; w_ref: (K, 1); o_ref: (1, BLOCK_D)
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)          # (K, 1)
    o_ref[...] = jnp.sum(x * w, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fedavg_agg_kernel(stack: jnp.ndarray, weights: jnp.ndarray,
                      *, interpret: bool = True) -> jnp.ndarray:
    """stack: (K, D) f32, weights: (K,) normalized.  Returns (D,) f32.

    D must be a multiple of BLOCK_D (ops.py pads)."""
    K, D = stack.shape
    assert D % BLOCK_D == 0, D
    grid = (D // BLOCK_D,)
    out = pl.pallas_call(
        _fedavg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, BLOCK_D), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_D), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, D), jnp.float32),
        interpret=interpret,
    )(weights.reshape(K, 1), stack)
    return out[0]
