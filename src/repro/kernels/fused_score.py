"""Pallas TPU kernel: fused candidate materialization for committee scoring.

The committee scores P candidate models ``global + update_i`` (paper
§III.B).  When updates live in the chain's int8 representation, the staged
path pays two f32 materializations of the (P, D) stack per validation
call:

  staged:  dequantize kernel   — P*B int8 read,  P*B f32 write
           add base params     — P*B f32 read + B f32 read, P*B f32 write
                                                 ~= 13*P*B bytes total
  fused:   P*B int8 read + B f32 read (base) + P*B f32 write (candidates)
                                                 ~=  5*P*B bytes total

This kernel streams each int8 update tile plus its per-tile scale into
VMEM, dequantizes **in-register**, and applies the delta during the base
parameter load — the candidate stack is written once and the intermediate
f32 update stack never exists.  It is the validation-side mirror of
``fused_agg``'s one-pass aggregation (PR 1): the quantized chain path
never materializes the f32 (P, D) stack twice.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import BLOCK_D


def _fused_candidates(p_ref, s_ref, q_ref, o_ref):
    # p_ref (1, BLOCK_D) f32 base params tile; q_ref (K, BLOCK_D) int8
    # update tiles; s_ref (K, 1) f32 per-tile scales
    o_ref[...] = p_ref[0, :] + q_ref[...].astype(jnp.float32) * s_ref[...]


def make_fused_candidates_fn(*, interpret: bool = True):
    """Unjitted ``(base, qstack, scales) -> candidates`` closure over the
    static kernel knobs — the form the validation score programs compose
    (``repro.fl.client._int8_score_program``: single-device jitted and
    shard_mapped per P-shard; ``fused_candidates_kernel`` below is the
    same closure jitted for direct use from ``ops``)."""
    return functools.partial(_fused, interpret=interpret)


def _fused(base: jnp.ndarray, qstack: jnp.ndarray, scales: jnp.ndarray,
           *, interpret: bool = True):
    K, D = qstack.shape
    assert D % BLOCK_D == 0, D
    assert qstack.dtype == jnp.int8, qstack.dtype
    assert base.shape == (D,), (base.shape, D)
    nblk = D // BLOCK_D
    assert scales.shape == (K, nblk), (scales.shape, K, nblk)
    return pl.pallas_call(
        _fused_candidates,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((1, BLOCK_D), lambda i: (0, i)),   # base params tile
            pl.BlockSpec((K, 1), lambda i: (0, i)),         # this tile's scales
            pl.BlockSpec((K, BLOCK_D), lambda i: (0, i)),   # int8 tiles
        ],
        out_specs=pl.BlockSpec((K, BLOCK_D), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((K, D), jnp.float32),
        interpret=interpret,
    )(base.reshape(1, D), scales, qstack)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_candidates_kernel(base: jnp.ndarray, qstack: jnp.ndarray,
                            scales: jnp.ndarray, *, interpret: bool = True):
    """base: (D,) f32 global params; qstack: (K, D) int8 update rows;
    scales: (K, D // BLOCK_D) f32.  Returns (K, D) f32 candidate rows
    ``base + dequant(qstack_k)`` in a single grid pass over the stack."""
    return _fused(base, qstack, scales, interpret=interpret)
