"""Pallas TPU kernels for the BFLC round hot path.

Layout: one module per kernel (fedavg_agg, cwmed + trimmed_mean, quantize,
fused_agg, fused_score) + ``ops`` (the padded, jit'd, method-dispatch
public layer) +
``ref`` (pure-jnp oracles the tests allclose against).  Import the public
API from here; reach into submodules only for the raw ``pallas_call``
wrappers.
"""
from repro.kernels.fused_agg import METHODS
from repro.kernels.tiling import BLOCK_D
from repro.kernels.ops import (
    Int8UpdateCodec,
    aggregate,
    aggregate_quantized,
    candidates_from_quantized,
    cwmed,
    dequantize,
    dequantize_pytree,
    fedavg_agg,
    padded_dim,
    quantize,
    quantize_pytree,
    quantize_stack,
    trimmed_mean,
)

__all__ = [
    "BLOCK_D",
    "METHODS",
    "Int8UpdateCodec",
    "aggregate",
    "aggregate_quantized",
    "candidates_from_quantized",
    "cwmed",
    "dequantize",
    "dequantize_pytree",
    "fedavg_agg",
    "padded_dim",
    "quantize",
    "quantize_pytree",
    "quantize_stack",
    "trimmed_mean",
]
