"""Pallas TPU kernels: per-tile symmetric int8 quantize / dequantize for
on-chain update storage (paper §IV.D storage optimization).

Updates stored as update blocks dominate chain growth; int8 with a per-tile
f32 scale cuts payload bytes ~4x and — beyond the paper — also cuts the HBM
/ ICI bytes of shipping updates to the committee.  One (1, BLOCK_D) tile per
grid step; scale = max|x| / 127 per tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import BLOCK_D


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[0, :].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    q_ref[0, :] = q
    s_ref[0, 0] = scale


def _dequant_kernel(q_ref, s_ref, o_ref):
    o_ref[0, :] = q_ref[0, :].astype(jnp.float32) * s_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_kernel(x: jnp.ndarray, *, interpret: bool = True):
    """x: (D,) f32 -> (q (D,) int8, scales (D // BLOCK_D,) f32)."""
    D = x.shape[0]
    assert D % BLOCK_D == 0, D
    nblk = D // BLOCK_D
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(nblk,),
        in_specs=[pl.BlockSpec((1, BLOCK_D), lambda i: (0, i))],
        out_specs=[
            pl.BlockSpec((1, BLOCK_D), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, D), jnp.int8),
            jax.ShapeDtypeStruct((1, nblk), jnp.float32),
        ],
        interpret=interpret,
    )(x.reshape(1, D))
    return q[0], s[0]


def _quant_stack_kernel(x_ref, q_ref, s_ref):
    # x_ref: (K, BLOCK_D) tile; per-row per-tile symmetric scales
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)          # (K, 1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_stack_kernel(stack: jnp.ndarray, *, interpret: bool = True):
    """stack: (K, D) f32 -> (q (K, D) int8, scales (K, D // BLOCK_D) f32).

    One grid pass quantizes all K rows tile-by-tile — the codec for packing
    a whole round's update blocks onto the chain in one kernel launch."""
    K, D = stack.shape
    assert D % BLOCK_D == 0, D
    nblk = D // BLOCK_D
    q, s = pl.pallas_call(
        _quant_stack_kernel,
        grid=(nblk,),
        in_specs=[pl.BlockSpec((K, BLOCK_D), lambda i: (0, i))],
        out_specs=[
            pl.BlockSpec((K, BLOCK_D), lambda i: (0, i)),
            pl.BlockSpec((K, 1), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((K, D), jnp.int8),
            jax.ShapeDtypeStruct((K, nblk), jnp.float32),
        ],
        interpret=interpret,
    )(stack)
    return q, s


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequantize_kernel(q: jnp.ndarray, scales: jnp.ndarray,
                      *, interpret: bool = True) -> jnp.ndarray:
    D = q.shape[0]
    assert D % BLOCK_D == 0, D
    nblk = D // BLOCK_D
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((1, BLOCK_D), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_D), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, D), jnp.float32),
        interpret=interpret,
    )(q.reshape(1, D), scales.reshape(1, nblk))
    return out[0]
