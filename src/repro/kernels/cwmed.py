"""Pallas TPU kernel: coordinate-wise median over K client updates (CwMed,
Yin et al. 2018 — the paper's robust-aggregation baseline, Fig. 4).

TPU adaptation (DESIGN.md §4): a CUDA CwMed sorts each coordinate in a
thread's registers (data-dependent branches, fine on GPU).  TPU VPU lanes
have no per-lane control flow, so we sort the K *rows* of a (K, BLOCK_D)
VMEM tile with an **odd-even transposition network**: K static phases of
vectorized min/max — branch-free, lane-parallel across all BLOCK_D
coordinates at once.  K is the committee's update count (small), so the
O(K^2) compare-exchanges are negligible against the HBM stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_D = 2048


def _cwmed_kernel(x_ref, o_ref, *, K: int):
    rows = [x_ref[k, :].astype(jnp.float32) for k in range(K)]
    # odd-even transposition sort: after K phases rows are sorted per lane
    for phase in range(K):
        start = phase % 2
        for i in range(start, K - 1, 2):
            lo = jnp.minimum(rows[i], rows[i + 1])
            hi = jnp.maximum(rows[i], rows[i + 1])
            rows[i], rows[i + 1] = lo, hi
    if K % 2 == 1:
        med = rows[K // 2]
    else:
        med = 0.5 * (rows[K // 2 - 1] + rows[K // 2])
    o_ref[0, :] = med


@functools.partial(jax.jit, static_argnames=("interpret",))
def cwmed_kernel(stack: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """stack: (K, D) f32 -> (D,) f32 per-coordinate median."""
    K, D = stack.shape
    assert D % BLOCK_D == 0, D
    out = pl.pallas_call(
        functools.partial(_cwmed_kernel, K=K),
        grid=(D // BLOCK_D,),
        in_specs=[pl.BlockSpec((K, BLOCK_D), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, BLOCK_D), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, D), jnp.float32),
        interpret=interpret,
    )(stack)
    return out[0]
