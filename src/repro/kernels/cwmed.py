"""Pallas TPU kernels: sorting-network robust aggregators over K client
updates — coordinate-wise median (CwMed, Yin et al. 2018, the paper's
robust-aggregation baseline of Fig. 4) and coordinate-wise trimmed mean.

TPU adaptation (DESIGN.md §4): a CUDA CwMed sorts each coordinate in a
thread's registers (data-dependent branches, fine on GPU).  TPU VPU lanes
have no per-lane control flow, so we sort the K *rows* of a (K, BLOCK_D)
VMEM tile with an **odd-even transposition network**: K static phases of
vectorized min/max — branch-free, lane-parallel across all BLOCK_D
coordinates at once.  K is the committee's update count (small), so the
O(K^2) compare-exchanges are negligible against the HBM stream.  The same
network serves both statistics: median takes the middle sorted row(s),
trimmed mean averages rows[trim : K-trim].
"""
from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import BLOCK_D


def sort_rows(rows: List[jnp.ndarray]) -> List[jnp.ndarray]:
    """Odd-even transposition network: after K phases rows are sorted
    ascending per lane.  Static unrolled — branch-free on the VPU."""
    K = len(rows)
    rows = list(rows)
    for phase in range(K):
        start = phase % 2
        for i in range(start, K - 1, 2):
            lo = jnp.minimum(rows[i], rows[i + 1])
            hi = jnp.maximum(rows[i], rows[i + 1])
            rows[i], rows[i + 1] = lo, hi
    return rows


def median_of_sorted(rows: List[jnp.ndarray]) -> jnp.ndarray:
    K = len(rows)
    if K % 2 == 1:
        return rows[K // 2]
    return 0.5 * (rows[K // 2 - 1] + rows[K // 2])


def trimmed_mean_of_sorted(rows: List[jnp.ndarray], trim: int) -> jnp.ndarray:
    K = len(rows)
    keep = rows[trim : K - trim]
    acc = keep[0]
    for r in keep[1:]:
        acc = acc + r
    return acc / float(len(keep))


def _cwmed_kernel(x_ref, o_ref, *, K: int):
    rows = sort_rows([x_ref[k, :].astype(jnp.float32) for k in range(K)])
    o_ref[0, :] = median_of_sorted(rows)


@functools.partial(jax.jit, static_argnames=("interpret",))
def cwmed_kernel(stack: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """stack: (K, D) f32 -> (D,) f32 per-coordinate median."""
    K, D = stack.shape
    assert D % BLOCK_D == 0, D
    out = pl.pallas_call(
        functools.partial(_cwmed_kernel, K=K),
        grid=(D // BLOCK_D,),
        in_specs=[pl.BlockSpec((K, BLOCK_D), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, BLOCK_D), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, D), jnp.float32),
        interpret=interpret,
    )(stack)
    return out[0]


def _trimmed_mean_kernel(x_ref, o_ref, *, K: int, trim: int):
    rows = sort_rows([x_ref[k, :].astype(jnp.float32) for k in range(K)])
    o_ref[0, :] = trimmed_mean_of_sorted(rows, trim)


@functools.partial(jax.jit, static_argnames=("trim", "interpret"))
def trimmed_mean_kernel(stack: jnp.ndarray, *, trim: int,
                        interpret: bool = True) -> jnp.ndarray:
    """stack: (K, D) f32 -> (D,) f32 coordinate-wise trimmed mean."""
    K, D = stack.shape
    assert D % BLOCK_D == 0, D
    if not 0 <= 2 * trim < K:
        raise ValueError(f"trim={trim} too large for K={K}")
    out = pl.pallas_call(
        functools.partial(_trimmed_mean_kernel, K=K, trim=trim),
        grid=(D // BLOCK_D,),
        in_specs=[pl.BlockSpec((K, BLOCK_D), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, BLOCK_D), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, D), jnp.float32),
        interpret=interpret,
    )(stack)
    return out[0]
