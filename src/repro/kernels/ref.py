"""Pure-jnp oracles for every kernel (the allclose targets of the tests)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.tiling import BLOCK_D  # the kernels' tiling


def fedavg_agg_ref(stack: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum(
        "k,kd->d", weights.astype(jnp.float32), stack.astype(jnp.float32)
    )


def cwmed_ref(stack: jnp.ndarray) -> jnp.ndarray:
    return jnp.median(stack.astype(jnp.float32), axis=0)


def trimmed_mean_ref(stack: jnp.ndarray, trim: int) -> jnp.ndarray:
    K = stack.shape[0]
    if not 0 <= 2 * trim < K:
        raise ValueError(f"trim={trim} too large for K={K}")
    s = jnp.sort(stack.astype(jnp.float32), axis=0)
    return s[trim : K - trim].mean(axis=0)


def quantize_ref(x: jnp.ndarray):
    D = x.shape[0]
    xb = x.astype(jnp.float32).reshape(-1, BLOCK_D)
    amax = jnp.max(jnp.abs(xb), axis=1)
    scales = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xb / scales[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(D), scales


def dequantize_ref(q: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    D = q.shape[0]
    return (q.reshape(-1, BLOCK_D).astype(jnp.float32) * scales[:, None]).reshape(D)


def quantize_stack_ref(stack: jnp.ndarray):
    """(K, D) f32 -> (q (K, D) int8, scales (K, D // BLOCK_D) f32)."""
    K, D = stack.shape
    xb = stack.astype(jnp.float32).reshape(K, -1, BLOCK_D)
    amax = jnp.max(jnp.abs(xb), axis=2)
    scales = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(
        jnp.round(xb / scales[:, :, None]), -127, 127
    ).astype(jnp.int8)
    return q.reshape(K, D), scales


def dequantize_stack_ref(q: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    """(K, D) int8 + (K, D // BLOCK_D) scales -> (K, D) f32."""
    K, D = q.shape
    return (
        q.reshape(K, -1, BLOCK_D).astype(jnp.float32) * scales[:, :, None]
    ).reshape(K, D)


def fused_agg_ref(
    q: jnp.ndarray,
    scales: jnp.ndarray,
    weights: jnp.ndarray,
    method: str = "fedavg",
    trim: int = 1,
) -> jnp.ndarray:
    """Staged oracle for the fused kernel: dequantize the whole stack to f32,
    then run the f32 reduction."""
    stack = dequantize_stack_ref(q, scales)
    if method == "fedavg":
        return fedavg_agg_ref(stack, weights)
    if method == "cwmed":
        return cwmed_ref(stack)
    if method == "trimmed_mean":
        return trimmed_mean_ref(stack, trim)
    raise ValueError(method)
