"""Pure-jnp oracles for every kernel (the allclose targets of the tests)."""
from __future__ import annotations

import jax.numpy as jnp

BLOCK_D = 2048  # must match the kernels' tiling


def fedavg_agg_ref(stack: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum(
        "k,kd->d", weights.astype(jnp.float32), stack.astype(jnp.float32)
    )


def cwmed_ref(stack: jnp.ndarray) -> jnp.ndarray:
    return jnp.median(stack.astype(jnp.float32), axis=0)


def quantize_ref(x: jnp.ndarray):
    D = x.shape[0]
    xb = x.astype(jnp.float32).reshape(-1, BLOCK_D)
    amax = jnp.max(jnp.abs(xb), axis=1)
    scales = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xb / scales[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(D), scales


def dequantize_ref(q: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    D = q.shape[0]
    return (q.reshape(-1, BLOCK_D).astype(jnp.float32) * scales[:, None]).reshape(D)
