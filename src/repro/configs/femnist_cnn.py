"""The paper's own global model: a compact AlexNet-role CNN for FEMNIST.

The paper trains AlexNet on FEMNIST (62-class 28x28 handwritten characters).
AlexNet's 11x11/5x5 convs are MXU-hostile and oversized for 28x28 inputs, so
per DESIGN.md §4 we use an equivalent-capacity 3x3 CNN filling the same role.
Pure-JAX init/apply — this is the pytree the BFLC chain stores and the
committee validates.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

ARCH_ID = "femnist-cnn"
NUM_CLASSES = 62
IMAGE_SHAPE = (28, 28, 1)


def init_params(key, *, width: int = 32, num_classes: int = NUM_CLASSES) -> Dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)

    def conv_init(k, shape):
        fan_in = shape[0] * shape[1] * shape[2]
        return jax.random.normal(k, shape) * math.sqrt(2.0 / fan_in)

    def fc_init(k, shape):
        return jax.random.normal(k, shape) * math.sqrt(2.0 / shape[0])

    w = width
    return {
        "conv1": {"w": conv_init(k1, (3, 3, 1, w)), "b": jnp.zeros((w,))},
        "conv2": {"w": conv_init(k2, (3, 3, w, 2 * w)), "b": jnp.zeros((2 * w,))},
        "fc1": {"w": fc_init(k3, (7 * 7 * 2 * w, 128)), "b": jnp.zeros((128,))},
        # zero-init output layer: calibrated logits at init (loss = ln 62),
        # keeps early local updates small enough to average across non-IID
        # clients (FL rounds aggregate K divergent updates)
        "fc2": {"w": jnp.zeros((128, num_classes)), "b": jnp.zeros((num_classes,))},
    }


# patch width (kh*kw*C_in) at or below which _conv lowers to im2col + GEMM
# instead of lax.conv.  Small-C_in convs (conv1: 3*3*1 = 9) are the round
# loop's hot spot once weights carry a batch axis: vmapping lax.conv over a
# P-stack of kernels (P candidate models in committee validation, P client
# models in local training) lowers to a grouped convolution that XLA:CPU
# executes at under 1 GFLOP/s, while the same contraction as a dot_general
# batches into one GEMM.  The unbatched forward is bitwise identical to
# lax.conv (same 9-tap summation); under vmapped weights and in the
# backward pass the accumulation order differs, so training numerics (and
# therefore seeded chain hashes / regression pins) shift within float
# tolerance — the differential test harness compares engines built from
# the same lowering, so parity suites are unaffected.  Above the limit
# (conv2: 3*3*8 = 72) the patch tensor's kh*kw-fold blowup costs more
# memory traffic than the grouped conv, so lax.conv stays.
_GEMM_PATCH_LIMIT = 32


def _conv(x, p):
    w = p["w"]
    kh, kw, cin, cout = w.shape
    if kh * kw * cin <= _GEMM_PATCH_LIMIT and kh % 2 == 1 and kw % 2 == 1:
        # im2col (SAME padding, stride 1): 9 shifted views concatenated on
        # the channel axis, then one (B*H*W, kh*kw*C) @ (kh*kw*C, F) GEMM
        H, W = x.shape[1], x.shape[2]
        xp = jnp.pad(x, ((0, 0), (kh // 2, kh // 2), (kw // 2, kw // 2), (0, 0)))
        taps = [
            xp[:, dy : dy + H, dx : dx + W, :]
            for dy in range(kh)
            for dx in range(kw)
        ]
        pat = jnp.concatenate(taps, axis=-1)
        return jnp.tensordot(pat, w.reshape(kh * kw * cin, cout), axes=1) + p["b"]
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + p["b"]


def _pool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def apply(params: Dict, images: jnp.ndarray) -> jnp.ndarray:
    """images: (B, 28, 28, 1) -> logits (B, 62)."""
    x = jax.nn.relu(_conv(images, params["conv1"]))
    x = _pool(x)                                   # 14x14
    x = jax.nn.relu(_conv(x, params["conv2"]))
    x = _pool(x)                                   # 7x7
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return x @ params["fc2"]["w"] + params["fc2"]["b"]


def loss_fn(params: Dict, images, labels) -> jnp.ndarray:
    logits = apply(params, images)
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()


def accuracy(params: Dict, images, labels) -> jnp.ndarray:
    return (apply(params, images).argmax(axis=-1) == labels).mean()
