"""Architecture registry: ``--arch <id>`` resolution for launcher/benchmarks."""
from __future__ import annotations

from typing import Dict

from repro.models.config import ModelConfig

from repro.configs import (
    gemma3_4b,
    hubert_xlarge,
    jamba_15_large,
    mixtral_8x7b,
    olmo_1b,
    phi4_mini,
    qwen15_4b,
    qwen2_vl_7b,
    qwen3_moe_30b,
    rwkv6_7b,
)

_MODULES = {
    m.ARCH_ID: m
    for m in (
        hubert_xlarge,
        qwen15_4b,
        olmo_1b,
        rwkv6_7b,
        mixtral_8x7b,
        qwen3_moe_30b,
        phi4_mini,
        jamba_15_large,
        gemma3_4b,
        qwen2_vl_7b,
    )
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str, **kw) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return _MODULES[arch_id].get_config(**kw)


def smoke_config(arch_id: str) -> ModelConfig:
    return _MODULES[arch_id].smoke_config()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (matches init_model's tree)."""
    D, V = cfg.d_model, cfg.vocab_size
    hd = cfg.resolved_head_dim
    total = 0
    if cfg.frontend != "audio":
        total += V * D                       # embed
    else:
        total += D                           # mask_emb
        total += 31 * (D // 16) * D + D      # conv pos
    if not cfg.tie_embeddings:
        total += V * D                       # lm head
    norm_p = {"rmsnorm": D, "layernorm": 2 * D, "layernorm_np": 0}[cfg.norm]
    for spec in cfg.all_layers():
        total += norm_p                      # norm1
        if spec.mixer.startswith("attn"):
            total += D * cfg.num_heads * hd + 2 * D * cfg.num_kv_heads * hd
            total += cfg.num_heads * hd * D
            if cfg.attention_bias:
                total += cfg.num_heads * hd + 2 * cfg.num_kv_heads * hd
        elif spec.mixer == "mamba":
            din, ds = cfg.mamba_d_inner, cfg.mamba_d_state
            dtr = cfg.resolved_dt_rank
            total += D * 2 * din + cfg.mamba_d_conv * din + din
            total += din * (dtr + 2 * ds) + dtr * din + din
            total += din * ds + din + din * D
            total += dtr + 2 * ds            # jamba dt/B/C norms
        elif spec.mixer == "rwkv6":
            L1, L2 = cfg.rwkv_lora_mix, cfg.rwkv_lora_decay
            total += D + 5 * D               # mus
            total += D * 5 * L1 + 5 * L1 * D # mix lora
            total += 5 * D * D               # r,k,v,g,o
            total += D + D * L2 + L2 * D     # decay
            total += D + 2 * D               # u + groupnorm
        if spec.mlp != "none":
            total += norm_p                  # norm2
        if spec.mlp == "dense":
            n = 3 if cfg.act in ("swiglu", "geglu") else 2
            total += n * D * cfg.d_ff
        elif spec.mlp == "moe":
            F = cfg.resolved_moe_d_ff
            n = 3 if cfg.act in ("swiglu", "geglu") else 2
            total += D * cfg.num_experts + cfg.num_experts * n * D * F
        elif spec.mlp == "rwkv_channel_mix":
            total += 2 * D + D * cfg.d_ff + cfg.d_ff * D + D * D
    total += norm_p                          # final norm
    return total


def active_param_count(cfg: ModelConfig) -> int:
    """Parameters active per token (MoE counts only top-k experts)."""
    if cfg.num_experts == 0:
        return param_count(cfg)
    full = param_count(cfg)
    F = cfg.resolved_moe_d_ff
    n = 3 if cfg.act in ("swiglu", "geglu") else 2
    per_expert = n * cfg.d_model * F
    n_moe = sum(1 for s in cfg.all_layers() if s.mlp == "moe")
    inactive = n_moe * (cfg.num_experts - cfg.num_experts_per_tok) * per_expert
    return full - inactive
