"""Jamba-1.5-Large 398B [arXiv:2403.19887] — hybrid Mamba+attention 1:7, MoE.

72L d_model=8192 64H (kv=8) d_ff=24576 vocab=65536, MoE 16 experts top-2.
Unit of 8 layers: 1 attention + 7 Mamba; MoE MLP on every other layer
(4 MoE per unit).  Hybrid: long_500k runs (bounded attention fraction).
"""
from repro.models.config import LayerSpec, ModelConfig

ARCH_ID = "jamba-1.5-large-398b"


def _unit():
    layers = []
    for i in range(8):
        mixer = "attn" if i == 0 else "mamba"
        mlp = "moe" if i % 2 == 1 else "dense"
        layers.append(LayerSpec(mixer=mixer, mlp=mlp))
    return tuple(layers)


def get_config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID,
        arch_type="hybrid",
        d_model=8192,
        vocab_size=65536,
        unit=_unit(),
        num_units=9,
        num_heads=64,
        num_kv_heads=8,
        d_ff=24576,
        moe_d_ff=24576,
        num_experts=16,
        num_experts_per_tok=2,
        mamba_d_state=16,
        mamba_d_conv=4,
        mamba_expand=2,
        citation="arXiv:2403.19887",
    )
    base.update(kw)
    return ModelConfig(**base)


def smoke_config() -> ModelConfig:
    unit = (
        LayerSpec(mixer="attn", mlp="dense"),
        LayerSpec(mixer="mamba", mlp="moe"),
    )
    return get_config(unit=unit, num_units=1, d_model=128, num_heads=4,
                      num_kv_heads=2, d_ff=256, moe_d_ff=256, vocab_size=1024,
                      num_experts=4, num_experts_per_tok=2, mamba_d_state=8)
