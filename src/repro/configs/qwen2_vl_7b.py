"""Qwen2-VL-7B [arXiv:2409.12191] — VLM backbone with M-RoPE.

28L d_model=3584 28H (kv=4) d_ff=18944 vocab=152064.
Vision encoder (ViT + projector) is a stub per DESIGN.md §5; the language
backbone consumes merged text-token + patch embeddings with (t,h,w) M-RoPE
position streams.
"""
from repro.models.config import ModelConfig, dense_unit

ARCH_ID = "qwen2-vl-7b"


def get_config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID,
        arch_type="vlm",
        d_model=3584,
        vocab_size=152064,
        unit=dense_unit(1),
        num_units=28,
        num_heads=28,
        num_kv_heads=4,
        d_ff=18944,
        attention_bias=True,
        rope="mrope",
        mrope_sections=(16, 24, 24),   # head_dim 128 -> half 64 = 16+24+24
        rope_theta=1e6,
        frontend="vision",
        citation="arXiv:2409.12191",
    )
    base.update(kw)
    return ModelConfig(**base)


def smoke_config() -> ModelConfig:
    return get_config(d_model=128, num_units=2, num_heads=4, num_kv_heads=2,
                      d_ff=256, vocab_size=1024, mrope_sections=(4, 6, 6))
