"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B] — fine-grained MoE: 128 experts top-8.

48L d_model=2048 32H (kv=4, head_dim=128) per-expert d_ff=768 vocab=151936.
"""
from repro.models.config import ModelConfig, moe_unit

ARCH_ID = "qwen3-moe-30b-a3b"


def get_config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID,
        arch_type="moe",
        d_model=2048,
        vocab_size=151936,
        unit=moe_unit(1),
        num_units=48,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=768,
        moe_d_ff=768,
        num_experts=128,
        num_experts_per_tok=8,
        rope_theta=1e6,
        citation="hf:Qwen/Qwen3-30B-A3B",
    )
    base.update(kw)
    return ModelConfig(**base)


def smoke_config() -> ModelConfig:
    return get_config(d_model=128, num_units=2, num_heads=4, num_kv_heads=2,
                      head_dim=32, d_ff=96, moe_d_ff=96, vocab_size=1024,
                      num_experts=4, num_experts_per_tok=2)
