"""RWKV-6 "Finch" 7B [arXiv:2404.05892] — attention-free, data-dependent decay.

32L d_model=4096 d_ff=14336 vocab=65536 head_dim=64 (64 heads).
Sub-quadratic: O(1) decode state; runs the long_500k shape.
"""
from repro.models.config import MLP_RWKV, LayerSpec, ModelConfig

ARCH_ID = "rwkv6-7b"


def get_config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID,
        arch_type="ssm",
        d_model=4096,
        vocab_size=65536,
        unit=(LayerSpec(mixer="rwkv6", mlp=MLP_RWKV),),
        num_units=32,
        d_ff=14336,
        rwkv_head_dim=64,
        rwkv_lora_mix=32,
        rwkv_lora_decay=64,
        norm="layernorm",
        citation="arXiv:2404.05892",
    )
    base.update(kw)
    return ModelConfig(**base)


def smoke_config() -> ModelConfig:
    return get_config(d_model=128, num_units=2, d_ff=256, vocab_size=1024,
                      rwkv_head_dim=32, rwkv_lora_mix=8, rwkv_lora_decay=8)
