"""HuBERT X-Large [arXiv:2106.07447] — audio encoder-only backbone.

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (k-means cluster targets).
Frontend (mel + conv feature extractor) is a stub per DESIGN.md §5; the
backbone trains with masked frame prediction.  Encoder-only: no decode step.
"""
from repro.models.config import ModelConfig, dense_unit

ARCH_ID = "hubert-xlarge"


def get_config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID,
        arch_type="audio",
        d_model=1280,
        vocab_size=504,
        unit=dense_unit(1),
        num_units=48,
        num_heads=16,
        num_kv_heads=16,
        d_ff=5120,
        act="gelu",
        norm="layernorm",
        causal=False,
        rope="none",          # HuBERT uses a conv positional embedding
        frontend="audio",
        citation="arXiv:2106.07447",
    )
    base.update(kw)
    return ModelConfig(**base)


def smoke_config() -> ModelConfig:
    return get_config(d_model=128, num_units=2, num_heads=4, num_kv_heads=4,
                      d_ff=256, vocab_size=54)
