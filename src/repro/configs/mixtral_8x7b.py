"""Mixtral-8x7B [arXiv:2401.04088] — MoE 8 experts top-2 with SWA.

32L d_model=4096 32H (kv=8) d_ff=14336/expert vocab=32000, window 4096.
Sliding-window attention makes the long_500k decode cache bounded.
"""
from repro.models.config import ModelConfig, moe_unit

ARCH_ID = "mixtral-8x7b"


def get_config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID,
        arch_type="moe",
        d_model=4096,
        vocab_size=32000,
        unit=moe_unit(1, mixer="attn_swa"),
        num_units=32,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        sliding_window=4096,
        num_experts=8,
        num_experts_per_tok=2,
        moe_d_ff=14336,
        rope_theta=1e6,
        citation="arXiv:2401.04088",
    )
    base.update(kw)
    return ModelConfig(**base)


def smoke_config() -> ModelConfig:
    return get_config(d_model=128, num_units=2, num_heads=4, num_kv_heads=2,
                      d_ff=256, moe_d_ff=256, vocab_size=1024,
                      num_experts=4, num_experts_per_tok=2, sliding_window=32)
