"""Phi-4-mini 3.8B [arXiv:2412.08905] — dense decoder: RoPE + SwiGLU + GQA.

32L d_model=3072 24H (kv=8) d_ff=8192 vocab=200064.
"""
from repro.models.config import ModelConfig, dense_unit

ARCH_ID = "phi4-mini-3.8b"


def get_config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID,
        arch_type="dense",
        d_model=3072,
        vocab_size=200064,
        unit=dense_unit(1),
        num_units=32,
        num_heads=24,
        num_kv_heads=8,
        d_ff=8192,
        tie_embeddings=True,
        citation="arXiv:2412.08905",
    )
    base.update(kw)
    return ModelConfig(**base)


def smoke_config() -> ModelConfig:
    return get_config(d_model=120, num_units=2, num_heads=4, num_kv_heads=2,
                      d_ff=256, vocab_size=1024)
