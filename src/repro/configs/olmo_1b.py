"""OLMo-1B [arXiv:2402.00838] — dense decoder with non-parametric LayerNorm.

16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304.
"""
from repro.models.config import ModelConfig, dense_unit

ARCH_ID = "olmo-1b"


def get_config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID,
        arch_type="dense",
        d_model=2048,
        vocab_size=50304,
        unit=dense_unit(1),
        num_units=16,
        num_heads=16,
        num_kv_heads=16,
        d_ff=8192,
        norm="layernorm_np",   # OLMo's non-parametric LN
        tie_embeddings=True,
        citation="arXiv:2402.00838",
    )
    base.update(kw)
    return ModelConfig(**base)


def smoke_config() -> ModelConfig:
    return get_config(d_model=128, num_units=2, num_heads=4, num_kv_heads=4,
                      d_ff=256, vocab_size=1024)
