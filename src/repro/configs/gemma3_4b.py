"""Gemma-3 4B [hf:google/gemma-3-1b-pt family] — 5:1 local:global attention.

34L d_model=2560 8H (kv=4, head_dim=256) d_ff=10240 vocab=262144,
sliding window 1024 on local layers; 128k-class context via the 5:1 pattern.
Unit of 6 layers (5 local + 1 global) x 5, tail of 4 local layers = 34.
Counts as sub-quadratic for long_500k (bounded global-layer fraction).
"""
from repro.models.config import LayerSpec, ModelConfig

ARCH_ID = "gemma3-4b"


def _unit():
    return tuple(
        LayerSpec(mixer="attn_local", mlp="dense") for _ in range(5)
    ) + (LayerSpec(mixer="attn_global", mlp="dense"),)


def get_config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID,
        arch_type="dense",
        d_model=2560,
        vocab_size=262144,
        unit=_unit(),
        num_units=5,
        tail=tuple(LayerSpec(mixer="attn_local", mlp="dense") for _ in range(4)),
        num_heads=8,
        num_kv_heads=4,
        head_dim=256,
        d_ff=10240,
        sliding_window=1024,
        act="geglu",
        scale_embeddings=True,
        tie_embeddings=True,
        rope_theta=1e6,
        citation="hf:google/gemma-3-1b-pt",
    )
    base.update(kw)
    return ModelConfig(**base)


def smoke_config() -> ModelConfig:
    unit = (
        LayerSpec(mixer="attn_local", mlp="dense"),
        LayerSpec(mixer="attn_global", mlp="dense"),
    )
    return get_config(unit=unit, num_units=1, tail=(), d_model=128,
                      num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256,
                      vocab_size=1024, sliding_window=16)
