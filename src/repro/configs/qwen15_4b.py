"""Qwen1.5-4B [hf:Qwen/Qwen1.5-0.5B family] — dense decoder with QKV bias.

40L d_model=2560 20H (kv=20) d_ff=6912 vocab=151936.
"""
from repro.models.config import ModelConfig, dense_unit

ARCH_ID = "qwen1.5-4b"


def get_config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID,
        arch_type="dense",
        d_model=2560,
        vocab_size=151936,
        unit=dense_unit(1),
        num_units=40,
        num_heads=20,
        num_kv_heads=20,
        d_ff=6912,
        attention_bias=True,
        rope_theta=5e6,
        citation="hf:Qwen/Qwen1.5-0.5B",
    )
    base.update(kw)
    return ModelConfig(**base)


def smoke_config() -> ModelConfig:
    return get_config(d_model=128, num_units=2, num_heads=4, num_kv_heads=4,
                      d_ff=256, vocab_size=1024)
