"""Version-tolerant ``shard_map``: one import site for every sharded path.

jax moved ``shard_map`` from ``jax.experimental.shard_map`` to the top-level
namespace and renamed the replication-check kwarg (``check_rep`` ->
``check_vma``) across 0.4.x -> 0.6.x.  The round engine, the MoE
expert-parallel path and the sharded kernels all go through this shim so the
repo runs on whichever jax the container bakes in.

``check`` defaults to False: the sharded kernels invoke ``pallas_call``
inside the mapped body, and pallas has no replication rule — the check
would reject an otherwise-correct program.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):                     # jax >= 0.6
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
else:                                             # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KW = "check_rep"


def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` with the replication-check kwarg normalized."""
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        **{_CHECK_KW: check},
    )
