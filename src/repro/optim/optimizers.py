"""Minimal pure-JAX optimizers (no optax dependency).

``Optimizer.init(params) -> state``;
``Optimizer.update(grads, state, params, step) -> (new_params, new_state)``.

``moment_dtype`` lets giant models (Jamba-398B on the 16x16 mesh) keep Adam
moments in bf16 — see EXPERIMENTS.md §Dry-run memory budgets.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params, step) -> (params, state)


def _cast_like(tree, dtype):
    if dtype is None:
        return tree
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def sgd(lr: Callable | float, *, momentum: float = 0.0, nesterov: bool = False,
        weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        if momentum == 0.0:
            return {}
        return {"mu": jax.tree.map(jnp.zeros_like, params)}

    def update(grads, state, params, step):
        lr_t = lr_fn(step)
        if weight_decay:
            grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
        if momentum == 0.0:
            new_params = jax.tree.map(lambda p, g: p - lr_t * g, params, grads)
            return new_params, state
        mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
        if nesterov:
            upd = jax.tree.map(lambda g, m: g + momentum * m, grads, mu)
        else:
            upd = mu
        new_params = jax.tree.map(lambda p, u: p - lr_t * u, params, upd)
        return new_params, {"mu": mu}

    return Optimizer(init, update)


def adamw(
    lr: Callable | float,
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    moment_dtype=None,
    grad_clip_norm: float = 0.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, moment_dtype or p.dtype), params)
        return {
            "m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
        }

    def update(grads, state, params, step):
        step = jnp.asarray(step, jnp.int32)
        if grad_clip_norm > 0:
            gnorm = jnp.sqrt(
                sum(
                    jnp.sum(jnp.square(g.astype(jnp.float32)))
                    for g in jax.tree.leaves(grads)
                )
            )
            scale = jnp.minimum(1.0, grad_clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads)
        m = jax.tree.map(
            lambda mo, g: (b1 * mo.astype(jnp.float32)
                           + (1 - b1) * g.astype(jnp.float32)).astype(mo.dtype),
            state["m"], grads,
        )
        v = jax.tree.map(
            lambda vo, g: (b2 * vo.astype(jnp.float32)
                           + (1 - b2) * jnp.square(g.astype(jnp.float32))).astype(vo.dtype),
            state["v"], grads,
        )
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t
        lr_t = lr_fn(step)

        def upd(p, mo, vo):
            mhat = mo.astype(jnp.float32) / bc1
            vhat = vo.astype(jnp.float32) / bc2
            u = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"m": m, "v": v}

    return Optimizer(init, update)
