"""Forced host-device bootstrap — the one place the device count lives.

The sharded round engine needs multiple devices; on CPU-only hosts XLA
fakes them via ``--xla_force_host_platform_device_count``.  The flag is
only read when jax initializes its backend, so callers (tests/conftest.py,
benchmarks/run.py) must invoke this before anything touches jax — which is
also why this module must never import jax itself.
"""
from __future__ import annotations

import os

FORCE_FLAG = "--xla_force_host_platform_device_count"
DEFAULT_HOST_DEVICES = 8


def force_host_devices(n: int = DEFAULT_HOST_DEVICES) -> None:
    """Idempotently append ``--xla_force_host_platform_device_count=n`` to
    ``XLA_FLAGS``.  An externally-provided force_host flag wins (CI matrix,
    local experiments)."""
    flags = os.environ.get("XLA_FLAGS", "")
    if FORCE_FLAG not in flags:
        os.environ["XLA_FLAGS"] = f"{flags} {FORCE_FLAG}={n}".strip()
