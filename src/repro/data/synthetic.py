"""Synthetic FEMNIST-like federated dataset (DESIGN.md §2).

The real FEMNIST bytes are unavailable offline; this generator reproduces the
*statistical shape* the paper's experiments rely on:

* 62 classes of 28x28 "handwritten-character-like" images: each class has a
  smooth low-frequency prototype; samples jitter it with per-writer style
  (a writer-specific smooth field), random shifts and pixel noise.
* 900 writers with unbalanced sample counts (log-normal) and non-IID class
  distributions.  IMPORTANT (paper fidelity): FEMNIST writers write ALL 62
  characters — the non-IID-ness is per-writer style + Dirichlet quantity
  skew, NOT restricted label support.  ``classes_per_client=62`` (default)
  matches that; small values create a much harsher label-partition regime
  (useful for stress tests, but it breaks the paper's BFLC ≈ FedAvg parity:
  committee validation on label-restricted shards locks in a class clique).

The classification task is genuinely learnable (protos are separable) but
non-trivial (style + noise), so FL aggregation quality differences — exactly
what Table I / Fig 4 measure — show up in accuracy.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np

NUM_CLASSES = 62
IMG = 28


@dataclass
class FederatedDataset:
    """Per-writer federated shards plus a held-out central test set."""

    client_images: List[np.ndarray]   # each (n_i, 28, 28, 1) float32
    client_labels: List[np.ndarray]   # each (n_i,) int32
    test_images: np.ndarray
    test_labels: np.ndarray

    @property
    def num_clients(self) -> int:
        return len(self.client_images)

    def client_sizes(self) -> np.ndarray:
        return np.array([len(x) for x in self.client_labels])

    def merged_train(self) -> Tuple[np.ndarray, np.ndarray]:
        """The stand-alone (centralized) training view of the same data."""
        return (
            np.concatenate(self.client_images, axis=0),
            np.concatenate(self.client_labels, axis=0),
        )


def _smooth_field(rng: np.random.Generator, scale: float, k: int = 4):
    """Random low-frequency 28x28 field from a kxk coefficient grid."""
    coeff = rng.normal(0, scale, (k, k))
    yy = np.linspace(0, np.pi, IMG)
    basis = np.stack([np.cos(yy * i) for i in range(k)])       # (k, 28)
    return basis.T @ coeff @ basis                              # (28, 28)


def make_femnist_like(
    *,
    num_clients: int = 900,
    mean_samples: int = 90,
    alpha: float = 0.5,
    classes_per_client: int = 62,
    test_size: int = 4000,
    noise: float = 0.35,
    seed: int = 0,
) -> FederatedDataset:
    rng = np.random.default_rng(seed)
    protos = np.stack([_smooth_field(rng, 1.0) for _ in range(NUM_CLASSES)])
    protos = protos / np.abs(protos).max(axis=(1, 2), keepdims=True)

    def sample(cls: int, n: int, style: np.ndarray) -> np.ndarray:
        base = protos[cls][None].repeat(n, 0)
        shifts = rng.integers(-2, 3, size=(n, 2))
        out = np.empty_like(base)
        for i in range(n):
            out[i] = np.roll(base[i], tuple(shifts[i]), axis=(0, 1))
        out = out + style[None] + rng.normal(0, noise, out.shape)
        return out.astype(np.float32)

    client_images, client_labels = [], []
    sizes = np.maximum(
        8, rng.lognormal(np.log(mean_samples), 0.5, num_clients).astype(int)
    )
    for ci in range(num_clients):
        style = _smooth_field(rng, 0.25)
        cls_pool = rng.choice(NUM_CLASSES, classes_per_client, replace=False)
        probs = rng.dirichlet(np.full(classes_per_client, alpha))
        labels = rng.choice(cls_pool, size=sizes[ci], p=probs)
        imgs = np.empty((sizes[ci], IMG, IMG), np.float32)
        for cls in np.unique(labels):
            idx = np.where(labels == cls)[0]
            imgs[idx] = sample(int(cls), len(idx), style)
        client_images.append(imgs[..., None])
        client_labels.append(labels.astype(np.int32))

    # IID test set, style-free (central evaluation view)
    test_labels = rng.integers(0, NUM_CLASSES, test_size).astype(np.int32)
    test_images = np.empty((test_size, IMG, IMG), np.float32)
    for cls in np.unique(test_labels):
        idx = np.where(test_labels == cls)[0]
        test_images[idx] = sample(int(cls), len(idx), np.zeros((IMG, IMG)))
    return FederatedDataset(
        client_images, client_labels, test_images[..., None], test_labels
    )


def batch_iterator(rng: np.random.Generator, images, labels, batch: int):
    n = len(labels)
    while True:
        idx = rng.integers(0, n, batch)
        yield images[idx], labels[idx]
