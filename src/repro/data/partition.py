"""Generic federated partitioners (for datasets that arrive centralized)."""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def dirichlet_partition(
    labels: np.ndarray, num_clients: int, alpha: float, seed: int = 0,
    min_size: int = 2,
) -> List[np.ndarray]:
    """Non-IID Dirichlet split: returns per-client index arrays."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    while True:
        buckets: List[List[int]] = [[] for _ in range(num_clients)]
        for c in classes:
            idx = np.where(labels == c)[0]
            rng.shuffle(idx)
            props = rng.dirichlet(np.full(num_clients, alpha))
            cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
            for b, part in zip(buckets, np.split(idx, cuts)):
                b.extend(part.tolist())
        if min(len(b) for b in buckets) >= min_size:
            break
    return [np.array(sorted(b)) for b in buckets]


def leaf_style_partition(
    labels: np.ndarray, num_clients: int, classes_per_client: int,
    seed: int = 0,
) -> List[np.ndarray]:
    """LEAF-style: each client sees only a few classes."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    per_class = {c: list(np.where(labels == c)[0]) for c in classes}
    for c in classes:
        rng.shuffle(per_class[c])
    out = []
    for _ in range(num_clients):
        chosen = rng.choice(classes, classes_per_client, replace=False)
        take = []
        for c in chosen:
            k = max(1, len(per_class[c]) // num_clients * 2)
            take.extend(per_class[c][:k])
            per_class[c] = per_class[c][k:] + per_class[c][:k]
        out.append(np.array(sorted(take)))
    return out
