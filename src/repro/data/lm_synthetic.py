"""Synthetic-but-learnable LM data: a sparse random Markov chain.

Each token has ``branching`` allowed successors with Zipf-ish weights, so a
model that learns the transition table drops from ln(V) to ~H(chain) nats —
giving the ~100M-model example (examples/train_100m.py) a real learning
signal without any external corpus, and giving FL clients distinguishable
dialects (per-client permutation of successor weights -> non-IID).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


class MarkovLM:
    def __init__(self, vocab: int, *, branching: int = 4, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.vocab = vocab
        self.succ = rng.integers(0, vocab, (vocab, branching))
        w = 1.0 / np.arange(1, branching + 1)
        self.probs = w / w.sum()
        self.branching = branching

    def entropy(self) -> float:
        return float(-(self.probs * np.log(self.probs)).sum())

    def sample(
        self, rng: np.random.Generator, batch: int, seq: int,
        dialect: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        """dialect: optional per-client permutation of successor weights."""
        probs = self.probs if dialect is None else self.probs[dialect]
        out = np.empty((batch, seq), np.int32)
        cur = rng.integers(0, self.vocab, batch)
        for t in range(seq):
            out[:, t] = cur
            choice = rng.choice(self.branching, size=batch, p=probs)
            cur = self.succ[cur, choice]
        return out

    def batch(self, rng, batch: int, seq: int):
        tokens = self.sample(rng, batch, seq + 1)
        return tokens[:, :-1], tokens[:, 1:]
