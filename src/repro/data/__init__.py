from repro.data.synthetic import FederatedDataset, make_femnist_like
from repro.data.partition import dirichlet_partition, leaf_style_partition
from repro.data.virtual import VirtualFederatedDataset

__all__ = [
    "FederatedDataset",
    "make_femnist_like",
    "dirichlet_partition",
    "leaf_style_partition",
    "VirtualFederatedDataset",
]
