"""Virtual client views: simulate 100k-client communities without 100k shards.

``VirtualFederatedDataset`` presents ``num_clients`` virtual clients over a
small base ``FederatedDataset`` by mapping virtual client ``i`` to base shard
``i % base.num_clients``.  The shard arrays are *aliased*, never copied, so a
100k-client community costs the same host memory as its 32-shard base — the
point of the hierarchical-round benchmarks is to measure the *round engine's*
memory/scaling behaviour (update-stack bytes, committee work) at large P, and
that behaviour depends only on how many clients train, not on how distinct
their bytes are.

The view quacks like ``FederatedDataset`` everywhere the round engines look:
``client_images[i]`` / ``client_labels[i]`` indexing, ``num_clients``,
``client_sizes()``, and the pass-through central test set.  ``merged_train``
delegates to the base (concatenating P aliased copies would defeat the
aliasing and answer no question the base doesn't).
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.data.synthetic import FederatedDataset


class _CyclicView:
    """Read-only list view of length ``n`` over ``base`` shards, cyclically."""

    def __init__(self, base: List[np.ndarray], n: int):
        self._base = base
        self._n = int(n)

    def __len__(self) -> int:
        return self._n

    def __getitem__(self, i: int) -> np.ndarray:
        i = int(i)
        if i < 0:
            i += self._n
        if not 0 <= i < self._n:
            raise IndexError(f"virtual client {i} out of range [0, {self._n})")
        return self._base[i % len(self._base)]

    def __iter__(self):
        for i in range(self._n):
            yield self[i]


class VirtualFederatedDataset(FederatedDataset):
    """``num_clients`` virtual clients cyclically aliasing a base dataset."""

    def __init__(self, base: FederatedDataset, num_clients: int):
        if num_clients < 1:
            raise ValueError(f"num_clients must be >= 1, got {num_clients}")
        if base.num_clients < 1:
            raise ValueError("base dataset has no clients")
        super().__init__(
            client_images=_CyclicView(base.client_images, num_clients),
            client_labels=_CyclicView(base.client_labels, num_clients),
            test_images=base.test_images,
            test_labels=base.test_labels,
        )
        self.base = base

    def merged_train(self):
        return self.base.merged_train()
