"""Batched serving demo: prefill + decode with the production decode step
(smoke-sized gemma3: 5:1 local:global attention with ring-buffer caches).

  PYTHONPATH=src python examples/serve_demo.py
"""
import subprocess
import sys
import os

if __name__ == "__main__":
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(repo, "src"))
    sys.exit(subprocess.call(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "gemma3-4b",
         "--smoke", "--batch", "4", "--prompt-len", "64", "--gen", "16"],
        env=env,
    ))
