"""Quickstart: decentralized federated learning with committee consensus.

Trains the paper's CNN on a synthetic FEMNIST-like federated dataset under
BFLC, prints per-round consensus stats, and verifies the chain.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.data import make_femnist_like
from repro.fl import BFLCConfig, BFLCRuntime, femnist_adapter


def main():
    print("Generating federated dataset (60 writers, non-IID)...")
    dataset = make_femnist_like(num_clients=60, mean_samples=80,
                                test_size=800, seed=1)
    adapter = femnist_adapter(width=16)

    cfg = BFLCConfig(
        active_proportion=0.3,      # k% of nodes participate per round
        committee_fraction=0.4,     # of active nodes -> committee
        k_updates=6,                # update blocks per round (chain layout k)
        local_steps=20,
        local_lr=0.02,
        election_method="by_score",
        seed=0,
    )
    runtime = BFLCRuntime(adapter, dataset, cfg)
    print(f"community: {dataset.num_clients} nodes | committee "
          f"{runtime.q_committee} | trainers/round {runtime.p_trainers}")

    for r in range(20):
        log = runtime.run_round(eval_test=(r % 5 == 4))
        line = (f"round {log.round:2d}: packed score "
                f"{log.mean_packed_score:.3f}, P*Q validations "
                f"{log.consensus_validations}")
        if log.test_accuracy is not None:
            line += f", test acc {log.test_accuracy:.3f}"
        print(line)

    print(f"\nchain height: {runtime.chain.height} "
          f"(1 genesis + 20 rounds x (1 model + {cfg.k_updates} updates))")
    print("chain verify:", runtime.chain.verify())
    t, _ = runtime.chain.latest_model()
    print(f"latest model block: round {t} at height "
          f"{runtime.chain.model_index(t)} (O(1) lookup)")


if __name__ == "__main__":
    main()
