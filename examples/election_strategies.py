"""§IV.B demo: compare the three committee-election strategies under a
moderate malicious presence.

  PYTHONPATH=src python examples/election_strategies.py
"""
from repro.core.election import BY_SCORE, MULTI_FACTOR, RANDOM
from repro.data import make_femnist_like
from repro.fl import BFLCConfig, BFLCRuntime, femnist_adapter


def main():
    ds = make_femnist_like(num_clients=60, mean_samples=80, test_size=600,
                           seed=1)
    adapter = femnist_adapter(width=16)
    for method in (RANDOM, BY_SCORE, MULTI_FACTOR):
        cfg = BFLCConfig(active_proportion=0.3, committee_fraction=0.4,
                         k_updates=6, local_steps=15, local_lr=0.02,
                         malicious_fraction=0.2, attack_sigma=1.0,
                         election_method=method, seed=0)
        rt = BFLCRuntime(adapter, ds, cfg)
        logs = rt.run(12, eval_every=12)
        packed_mal = sum(l.packed_malicious for l in logs)
        print(f"{method:13s}: final acc {logs[-1].test_accuracy:.3f}, "
              f"malicious packed {packed_mal}/{12 * cfg.k_updates}")


if __name__ == "__main__":
    main()
