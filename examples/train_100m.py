"""End-to-end driver: trains a ~100M-parameter decoder for a few hundred
steps on synthetic Markov-chain data with the production train_step — the
same step the multi-pod dry-run lowers, here on the host mesh.

  PYTHONPATH=src python examples/train_100m.py [--steps 300] [--mode bflc]
"""
import argparse
import sys

from repro.launch.train import run_lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--mode", choices=["standard", "bflc"], default="standard")
    args = ap.parse_args()
    ns = argparse.Namespace(
        steps=args.steps, batch=8, seq=256, lr=3e-4, mode=args.mode,
        cohorts=4, committee=4, small=False, use_all_devices=False,
        ckpt="examples_100m.ckpt", log_every=20,
    )
    final = run_lm(ns)
    print(f"final loss: {final:.3f}")


if __name__ == "__main__":
    main()
