"""Swapping round stages through the registry (the pipeline API).

The BFLC round is seven pluggable stages (repro.fl.pipeline).  This demo
registers a custom **Packer** that bypasses the committee — it packs the
first k collected updates unweighted, exactly Basic FL's selection rule —
and runs it inside the full BFLC runtime (chain, election, incentives
untouched).  Compared against the real committee packer and the FLTrainer
baseline under a 25% malicious population: the no-committee packer loses
the poisoning filter and tracks the undefended baseline.

No pipeline internals are modified — the stage is registered by name and
named when building the runtime.

  PYTHONPATH=src python examples/custom_stage.py
"""
from repro.api import build_runtime
from repro.data import make_femnist_like
from repro.fl import femnist_adapter, train_standalone
from repro.fl.pipeline import register


@register("packer", "no_committee")
def pack_no_committee(ctx):
    """Basic FL selection inside BFLC: first k updates, no score filter,
    uniform weights.  Chain layout still needs exactly k update blocks."""
    k = ctx.cfg.k_updates
    ids = list(ctx.updates)[:k]
    while len(ids) < k:
        ids.append(ids[0])
    ctx.packed_ids = ids
    ctx.packed_scores = [0.0] * len(ids)
    ctx.packed_updates = [ctx.updates[u] for u in ids]
    ctx.weights = None
    for i, u in enumerate(ids):
        ctx.chain.append_update(ctx.packed_updates[i], u, 0.0)


def main():
    ds = make_femnist_like(num_clients=36, mean_samples=60, test_size=400,
                           seed=2)
    adapter = femnist_adapter(width=8)
    cfg = dict(active_proportion=0.4, committee_fraction=0.3, k_updates=4,
               local_steps=8, local_batch=32, malicious_fraction=0.25,
               attack_sigma=1.5, seed=0)
    rounds = 5
    # warm start: committee validation discriminates only once honest
    # scores separate from poisoned ones (same regime as Fig. 4)
    warm, _ = train_standalone(adapter, ds, steps=150, batch=32, lr=0.05,
                               eval_every=10**6)

    rt = build_runtime(adapter, ds, cfg, initial_params=warm)
    rt.run(rounds, eval_every=rounds)
    print(f"committee packer   : acc {rt.logs[-1].test_accuracy:.3f}, "
          f"malicious packed {sum(l.packed_malicious for l in rt.logs)}"
          f"/{rounds * rt.cfg.k_updates}")

    rt2 = build_runtime(adapter, ds, cfg, initial_params=warm,
                        stages={"packer": "no_committee"})
    rt2.run(rounds, eval_every=rounds)
    assert rt2.chain.verify()
    print(f"no-committee packer: acc {rt2.logs[-1].test_accuracy:.3f}, "
          f"malicious packed {sum(l.packed_malicious for l in rt2.logs)}"
          f"/{rounds * rt2.cfg.k_updates}")

    fl = build_runtime(adapter, ds,
                       {k: cfg[k] for k in ("active_proportion",
                                            "local_steps", "local_batch",
                                            "malicious_fraction",
                                            "attack_sigma", "seed")},
                       baseline=True, initial_params=warm)
    fl.run(rounds, eval_every=rounds)
    print(f"FLTrainer baseline : acc {fl.accuracies[-1]:.3f} "
          f"(same pipeline, committee stages no-ops)")


if __name__ == "__main__":
    main()
