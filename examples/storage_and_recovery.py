"""§IV.D + §IV.C demo: chain storage schemes and post-attack failback.

1. trains a few BFLC rounds,
2. shows the three storage schemes (full / pruned / off-chain) and the int8
   update codec,
3. simulates a successful poisoning of the latest model block and recovers
   by failing back to a historical model block (the paper's §IV.C remedy).

  PYTHONPATH=src python examples/storage_and_recovery.py
"""
import jax
import jax.numpy as jnp

from repro.core.blockchain import Chain
from repro.core.storage import OffChainStore
from repro.data import make_femnist_like
from repro.fl import BFLCConfig, BFLCRuntime, femnist_adapter
from repro.kernels.ops import dequantize_pytree, quantize_pytree


def main():
    ds = make_femnist_like(num_clients=40, mean_samples=60, test_size=400,
                           seed=4)
    adapter = femnist_adapter(width=8)
    cfg = BFLCConfig(active_proportion=0.5, committee_fraction=0.4,
                     k_updates=6, local_steps=10, seed=0)
    rt = BFLCRuntime(adapter, ds, cfg)
    rt.run(6, eval_every=6)
    chain = rt.chain
    print(f"chain height {chain.height}, resident bytes "
          f"{chain.storage_bytes()/1e6:.2f} MB")

    # --- storage optimization (§IV.D) ---
    dropped = chain.prune(keep_rounds=2)
    print(f"pruned {dropped} historical payloads -> "
          f"{chain.storage_bytes()/1e6:.2f} MB; verify={chain.verify()}")

    # int8 codec for a model-sized update (beyond-paper)
    update = jax.tree.map(
        lambda x: 0.01 * jnp.ones_like(x), rt.global_params()
    )
    blob, unravel = quantize_pytree(update)
    raw = sum(x.nbytes for x in jax.tree.leaves(update))
    packed = blob["q"].nbytes + blob["scales"].nbytes
    print(f"int8 update codec: {raw} B -> {packed} B ({raw/packed:.1f}x)")

    # --- failback (§IV.C) ---
    t, good = chain.latest_model()
    acc_before = rt.evaluate()
    # a malicious committee majority packs a poisoned model block
    poisoned = jax.tree.map(
        lambda x: jnp.asarray(
            jax.random.normal(jax.random.PRNGKey(0), x.shape), x.dtype
        ), good,
    )
    for i in range(chain.k):
        chain.append_update(update, uploader=0, score=0.99)
    chain.append_model(poisoned, t + 1)
    acc_poisoned = rt.evaluate()
    # recovery: any honest node replays from a historical model block
    recovered = chain.model_at_round(t)
    rt.chain = Chain(cfg.k_updates)
    rt.chain.append_model(recovered, 0)
    acc_recovered = rt.evaluate()
    print(f"accuracy before={acc_before:.3f} poisoned={acc_poisoned:.3f} "
          f"recovered={acc_recovered:.3f}")
    assert abs(acc_recovered - acc_before) < 1e-6


if __name__ == "__main__":
    main()
