"""Fig.4-style demo: BFLC vs FedAvg vs CwMed under a collusive
Gaussian-perturbation attack (30% malicious nodes).

  PYTHONPATH=src python examples/malicious_attack.py
"""
from repro.data import make_femnist_like
from repro.fl import BFLCConfig, BFLCRuntime, FLConfig, FLTrainer, femnist_adapter

MAL = 0.3
ROUNDS = 15


def main():
    ds = make_femnist_like(num_clients=60, mean_samples=80, test_size=800,
                           seed=1)
    adapter = femnist_adapter(width=16)

    print(f"=== BFLC with {MAL:.0%} malicious (collusive scoring) ===")
    cfg = BFLCConfig(active_proportion=0.3, committee_fraction=0.3,
                     k_updates=6, local_steps=20, local_lr=0.02,
                     malicious_fraction=MAL, attack="gaussian",
                     attack_sigma=1.0, collusion=True, seed=0)
    rt = BFLCRuntime(adapter, ds, cfg)
    logs = rt.run(ROUNDS, eval_every=5)
    packed_mal = sum(l.packed_malicious for l in logs)
    print(f"malicious updates packed on-chain: {packed_mal} / "
          f"{ROUNDS * cfg.k_updates}")
    print(f"final accuracy: {logs[-1].test_accuracy:.3f}")

    for name, agg in (("Basic FL (FedAvg)", "fedavg"), ("CwMed", "cwmed")):
        print(f"\n=== {name} with {MAL:.0%} malicious ===")
        fl = FLTrainer(adapter, ds, FLConfig(
            active_proportion=0.3, local_steps=20, local_lr=0.02,
            aggregation=agg, malicious_fraction=MAL, attack="gaussian",
            attack_sigma=1.0, seed=0))
        accs = fl.run(ROUNDS, eval_every=5)
        print(f"final accuracy: {accs[-1]:.3f}")


if __name__ == "__main__":
    main()
