"""Hierarchical-round scaling: peak update-stack bytes vs community size.

Sweeps the simulated community size P from 32 to 100k+ clients through the
two-tier round engine (``repro.fl.hier``, ``build_runtime(..., tiers=S)``)
and reports the measured high-water mark of update-stack bytes held at once
(``HierState.peak_stack_bytes``) against the O(P·D) stack a flat round
would materialize (``flat_stack_bytes``).  The point of the subsystem is
that the peak is bounded by the largest *slice* (~``SLICE`` trainers), not
by P — the rows make that bound a tracked number.

Large P is simulated with ``VirtualFederatedDataset``: virtual client ``i``
aliases base shard ``i % 32`` (no data copies), so the sweep measures the
round engine's behaviour — slicing, streaming ingest, per-slice fused int8
consensus, the tier-2 committee round — at 100k clients without 100k
shards.  Each P runs the full quantized sharded engine: int8 chain blobs,
fused score-from-int8 tier-1 validation (the row-quant cache feeds the
sub-aggregation), shard_mapped training over the forced host devices.

Wall-clock per round is reported too (first round, so XLA compilation is
included — these rows track memory scaling, not steady-state latency; the
steady-state stage timings live in ``round_bench``).

``benchmarks.run`` merges these rows into ``BENCH_round.json`` alongside
the flat round-loop stage timings.  Standalone CLI (the CI bench smoke
step runs ``--smoke``):

  PYTHONPATH=src python -m benchmarks.hier_bench --smoke
  PYTHONPATH=src python -m benchmarks.hier_bench --full   # adds P=102400
"""
from __future__ import annotations

import math
import time

from benchmarks.common import emit

SLICE = 256   # target tier-1 slice width (trainers + sub-committee)
Q2 = 4        # round (tier-2) committee size, held fixed across the sweep


def _tiers_for(pool: int) -> int:
    """S sized so each slice holds ~SLICE nodes (>= 2: the tiered engine's
    floor; the partitioner needs 4 nodes per slice)."""
    return max(2, math.ceil(pool / SLICE))


def run(full: bool = False, rounds: int | None = None, smoke: bool = False):
    import jax

    from repro.api import build_runtime
    from repro.data import VirtualFederatedDataset, make_femnist_like
    from repro.fl import femnist_adapter
    from repro.launch.mesh import make_round_mesh

    rounds = 1 if rounds is None else rounds
    sweep = ((32, 256) if smoke
             else (32, 1024, 10240) + ((102400,) if full else ()))
    # 32 base shards aliased by every virtual community in the sweep
    base = make_femnist_like(num_clients=32, mean_samples=40, test_size=64,
                             seed=5)
    adapter = femnist_adapter(width=2)
    ndev = min(8, len(jax.devices()))
    mesh = make_round_mesh(ndev) if ndev > 1 else None

    print("# hierarchical rounds: peak update-stack bytes (nbytes column) "
          "vs flat O(P*D) equivalent, fused int8 engine, "
          f"ndev={ndev}, slice~{SLICE}")
    print("hier_P,us_per_round")
    for P in sweep:
        ds = VirtualFederatedDataset(base, P)
        S = _tiers_for(P - Q2)
        cfg = dict(
            active_proportion=1.0,           # every virtual client trains
            committee_fraction=Q2 / P,       # q_committee = Q2, q_sub >= 3
            k_updates=8,
            local_steps=1, local_batch=8, val_batch=16,
            quantize_chain=True, use_kernels=True,
            seed=0,
        )
        inner = "committee_int8_sharded" if mesh is not None else \
            "committee_int8"
        rt = build_runtime(adapter, ds, cfg, mesh=mesh, tiers=S,
                           stages={"validator": inner})
        t0 = time.perf_counter()
        rt.run(rounds, eval_every=rounds + 1)
        us = (time.perf_counter() - t0) / rounds * 1e6
        assert rt.chain.verify()
        log = rt.hier_logs[-1]
        peak, flat = log["peak_stack_bytes"], log["flat_stack_bytes"]
        emit(
            f"hier_P{P}", us,
            derived=(f"S={S};slice_rows={log['max_slice_rows']};"
                     f"flat_bytes={flat};ratio={flat / max(peak, 1):.1f};"
                     f"t1_validations={log['t1_validations']};"
                     f"rounds={rounds};compile_included=1"),
            nbytes=peak,
        )
        # the claimed bound: the peak is one slice's padded stack (+ the
        # S sub-aggregate blocks at tier 2), never the O(P*D) flat stack
        if P >= 1024:
            assert peak < flat, (P, peak, flat)


if __name__ == "__main__":
    import argparse

    # forced host devices for the sharded engine, set before jax touches
    # its backend (module imports above don't query devices)
    from repro.hostdevices import force_host_devices

    force_host_devices()

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="adds the 102400-client row (slow)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI sanity scale: P=32 and P=256 only")
    ap.add_argument("--rounds", type=int, default=None,
                    help="rounds per community size (default 1)")
    ap.add_argument("--out", default=None,
                    help="also write the emitted rows as JSON (the CI "
                         "smoke step uploads this)")
    args = ap.parse_args()
    run(full=args.full, rounds=args.rounds, smoke=args.smoke)
    if args.out:
        import json

        from benchmarks.common import RESULTS

        with open(args.out, "w") as f:
            json.dump(RESULTS, f, indent=2)
        print(f"# wrote {args.out}")
