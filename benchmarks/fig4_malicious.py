"""Paper Fig. 4: global-model accuracy under collusive Gaussian-noise attacks
at increasing malicious proportions — BFLC vs Basic FL (FedAvg) vs CwMed.

Paper setting: 10% active nodes, 20% of them elected committee; malicious
committee members give random high scores (0.9-1.0) to malicious updates.
Reproduced claim: BFLC tolerates a much higher malicious fraction.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.data import make_femnist_like
from repro.fl import BFLCConfig, BFLCRuntime, FLConfig, FLTrainer, femnist_adapter


def run(full: bool = False):
    clients = 120 if full else 60
    rounds = 50 if full else 12
    fracs = (0.0, 0.1, 0.2, 0.3, 0.4, 0.5) if full else (0.0, 0.2, 0.4)
    ds = make_femnist_like(
        num_clients=clients, mean_samples=80, test_size=1200 if full else 600,
        seed=1,
    )
    adapter = femnist_adapter(width=16)
    t0 = time.time()
    # Warm start: committee validation discriminates only once honest scores
    # separate from poisoned ones (the paper's Fig. 4 operates on converging
    # models; the cold-start window is a vulnerability we report separately
    # in EXPERIMENTS.md).
    from repro.fl.baselines import train_standalone

    warm, _ = train_standalone(adapter, ds, steps=250, batch=64, lr=0.05,
                               eval_every=10**6)

    print("# Fig4: accuracy under collusive gaussian attack")
    print("framework," + ",".join(f"{f:.0%}" for f in fracs))
    rows = {"BFLC": [], "BasicFL": [], "CwMed": []}
    packed_mal = []
    for frac in fracs:
        # paper: 10% of 900 active, 20% committee -> q=18.  At reduced client
        # counts the same FRACTIONS give q=2, where median scoring is not
        # robust (one colluder controls it) — keep the committee >= 5 so the
        # scaled run preserves the paper's q >> 2 regime.
        cfg = BFLCConfig(
            active_proportion=0.25, committee_fraction=0.35,
            k_updates=max(3, int(clients * 0.25 * 0.5)),
            local_steps=20, local_batch=32, malicious_fraction=frac,
            attack="gaussian", attack_sigma=1.0, collusion=True, seed=0,
        )
        rt = BFLCRuntime(adapter, ds, cfg, initial_params=warm)
        rt.run(rounds, eval_every=rounds)
        rows["BFLC"].append(rt.logs[-1].test_accuracy)
        packed_mal.append(
            sum(l.packed_malicious for l in rt.logs)
            / (cfg.k_updates * rounds)
        )

        for name, agg in (("BasicFL", "fedavg"), ("CwMed", "cwmed")):
            fl = FLTrainer(adapter, ds, FLConfig(
                active_proportion=0.2, local_steps=20, local_batch=32,
                aggregation=agg, malicious_fraction=frac,
                attack="gaussian", attack_sigma=1.0, seed=0,
            ), initial_params=warm)
            fl.run(rounds, eval_every=rounds)
            rows[name].append(fl.accuracies[-1])

    for name, vals in rows.items():
        print(f"{name}," + ",".join(f"{v:.4f}" for v in vals))
    print("BFLC_packed_malicious_rate," +
          ",".join(f"{v:.3f}" for v in packed_mal))
    dt = (time.time() - t0) * 1e6
    emit("fig4_malicious", dt / max(len(fracs), 1),
         f"bflc_at_max_frac={rows['BFLC'][-1]:.3f};"
         f"fedavg_at_max_frac={rows['BasicFL'][-1]:.3f}")
    return rows


if __name__ == "__main__":
    run(full=True)
