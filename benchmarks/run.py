"""Benchmark harness entry: one function per paper table/figure + systems
benchmarks.  Prints ``name,us_per_call,derived`` CSV lines and writes the
kernel rows to ``BENCH_kernels.json`` and the round-loop stage timings to
``BENCH_round.json`` (name -> {us, bytes}) so the perf trajectory is
machine-trackable across PRs.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time
import traceback

from repro.hostdevices import force_host_devices

# round_bench's sharded-engine rows need multiple devices; the flag must
# land before jax initializes its backend (first device query), i.e. before
# any benchmark runs.  An externally-set force_host flag wins.  NOTE: this
# applies to EVERY section (single-device work still runs on device 0, but
# the XLA CPU thread-pool layout differs) — BENCH_kernels.json and
# BENCH_round.json snapshots are regenerated under this environment since
# PR 3; don't compare them against pre-PR-3 single-device numbers.
force_host_devices()

from benchmarks import common
from benchmarks import (
    committee_ablation,
    consensus_cost,
    fig3_attack_probability,
    fig4_malicious,
    hier_bench,
    kernel_bench,
    roofline,
    round_bench,
    serve_bench,
    storage_opt,
    table1_accuracy,
)

ALL = {
    "fig3_attack_probability": fig3_attack_probability.run,
    "consensus_cost": consensus_cost.run,
    "kernel_bench": kernel_bench.run,
    "round_bench": round_bench.run,
    "hier_bench": hier_bench.run,
    "serve_bench": serve_bench.run,
    "storage_opt": storage_opt.run,
    "table1_accuracy": table1_accuracy.run,
    "fig4_malicious": fig4_malicious.run,
    "committee_ablation": committee_ablation.run,
    "roofline": roofline.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweeps (slow)")
    ap.add_argument("--only", default=None, choices=list(ALL))
    args = ap.parse_args()

    names = [args.only] if args.only else list(ALL)
    failures = 0
    sections = {}
    for name in names:
        print(f"\n=== {name} ===")
        t0 = time.time()
        common.RESULTS.clear()
        try:
            ALL[name](full=args.full)
            sections[name] = dict(common.RESULTS)
        except Exception:  # noqa: BLE001
            # no sections entry: a partial run must not overwrite the last
            # complete machine-readable snapshot
            failures += 1
            traceback.print_exc()
            print(f"{name},0.0,FAILED")
        print(f"# {name} took {time.time()-t0:.1f}s")

    root = pathlib.Path(__file__).resolve().parent.parent
    if "kernel_bench" in sections:
        out = root / "BENCH_kernels.json"
        out.write_text(json.dumps(sections["kernel_bench"], indent=2) + "\n")
        print(f"# wrote {out}")
    # BENCH_round.json carries the flat round-loop stage timings AND the
    # hierarchical-round memory rows: merge whichever sections ran into the
    # existing snapshot so a --only run of one doesn't drop the other's
    # rows (renamed rows must be pruned by hand — keys merge, not replace)
    ran = [s for s in ("round_bench", "hier_bench") if s in sections]
    if ran:
        out = root / "BENCH_round.json"
        data = json.loads(out.read_text()) if out.exists() else {}
        for section in ran:
            data.update(sections[section])
        out.write_text(json.dumps(data, indent=2) + "\n")
        print(f"# wrote {out}")
    # serving rows live in their own snapshot: same merge discipline as
    # BENCH_round.json so a --only run keeps unrelated rows intact
    if "serve_bench" in sections:
        out = root / "BENCH_serve.json"
        data = json.loads(out.read_text()) if out.exists() else {}
        data.update(sections["serve_bench"])
        out.write_text(json.dumps(data, indent=2) + "\n")
        print(f"# wrote {out}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
