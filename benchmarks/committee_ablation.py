"""Beyond-paper ablation: committee fraction vs robustness and accuracy.

§IV.B says election strategy trades generalization vs attack cost, and
§III.B claims the rotating committee gives k-fold cross-validation.  This
ablation sweeps the committee fraction under a fixed 25% malicious presence
and reports (accuracy, malicious-packed rate, consensus cost) — the
three-way trade-off the paper discusses qualitatively.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.consensus import consensus_cost
from repro.data import make_femnist_like
from repro.fl import BFLCConfig, BFLCRuntime, femnist_adapter


def run(full: bool = False):
    clients = 80 if full else 48
    rounds = 30 if full else 10
    fracs = (0.2, 0.3, 0.4, 0.5) if full else (0.2, 0.4)
    ds = make_femnist_like(num_clients=clients, mean_samples=70,
                           test_size=800 if full else 400, seed=2)
    adapter = femnist_adapter(width=16)
    t0 = time.time()
    print("# committee fraction ablation (25% malicious, gaussian sigma=1)")
    print("committee_frac,final_acc,malicious_packed_rate,validations_per_round")
    for cf in fracs:
        cfg = BFLCConfig(
            active_proportion=0.4, committee_fraction=cf,
            k_updates=max(4, int(clients * 0.4 * (1 - cf) * 0.8)),
            local_steps=15, local_batch=32, malicious_fraction=0.25,
            attack="gaussian", attack_sigma=1.0, seed=0,
        )
        rt = BFLCRuntime(adapter, ds, cfg)
        logs = rt.run(rounds, eval_every=rounds)
        rate = sum(l.packed_malicious for l in logs) / (cfg.k_updates * rounds)
        val = logs[-1].consensus_validations
        print(f"{cf:.1f},{logs[-1].test_accuracy:.4f},{rate:.3f},{val}")
    emit("committee_ablation", (time.time() - t0) * 1e6 / len(fracs), "")


if __name__ == "__main__":
    run(full=True)
