"""Serving benchmark: static-batch baseline vs continuous batching, plus a
mid-trace chain hot-swap, on one synthetic heavy-traffic Poisson trace.

Three measured rows (merged into ``BENCH_serve.json`` by ``benchmarks.run``)
carry tokens/s, TTFT and end-to-end latency p50/p99, and slot occupancy:

  serve_static            whole-batch barrier admission (the legacy
                          ``launch/serve.py`` discipline)
  serve_continuous        slot-based in-flight batching, same trace
  serve_continuous_swap   in-flight batching while the watched chain
                          commits a new model block mid-trace

plus ``serve_decode_hlo`` — modeled per-decoded-token dot FLOPs/bytes of
the compiled decode step (``hlo_stats.decode_per_token_stats``), the
serving analogue of the round kernels' modeled-bytes rows.

The same engine serves both policies; only the admission rule differs, so
the static-vs-continuous gap is the scheduling win, not a code-path
artifact.  The model is the CPU-friendly olmo-1b smoke config — the rows
track the engine, not the model.

  PYTHONPATH=src python -m benchmarks.serve_bench --smoke [--out F]
"""
from __future__ import annotations

from benchmarks.common import RESULTS, emit


def _metrics_row(name: str, report) -> dict:
    m = report.metrics()
    us_per_tok = (m["wall_s"] / m["generated_tokens"] * 1e6
                  if m["generated_tokens"] else 0.0)
    emit(
        name, us_per_tok,
        derived=(f"tok_s={m['tok_s']};ttft_p99_ms={m['ttft_p99_ms']};"
                 f"lat_p99_ms={m['latency_p99_ms']};occ={m['occupancy']};"
                 f"swaps={m['swaps']}"),
    )
    RESULTS[name].update(m)
    return m


def run(full: bool = False, smoke: bool = False):
    import jax
    import numpy as np

    from repro.configs import registry
    from repro.core.blockchain import Chain
    from repro.launch.hlo_stats import decode_per_token_stats
    from repro.models import init_cache, init_model
    from repro.serve import ChainParamSource, ServeEngine, make_poisson_trace

    cfg = registry.smoke_config("olmo-1b")
    if smoke:
        slots, max_len, n_req, rate = 4, 48, 12, 200.0
        prompt_lens, gen_lens = (8, 16, 24), (4, 8, 16)
    else:
        slots, max_len, n_req, rate = 8, 96, 48, 400.0
        prompt_lens, gen_lens = (16, 32, 48), (8, 16, 32)

    params0 = init_model(jax.random.PRNGKey(0), cfg)
    trace = make_poisson_trace(
        num_requests=n_req, rate=rate, prompt_lens=prompt_lens,
        gen_lens=gen_lens, vocab_size=cfg.vocab_size, seed=0,
    )
    budget = sum(r.max_new for r in trace)

    print(f"# serving trace: {n_req} Poisson requests @ {rate}/s, "
          f"prompts {prompt_lens}, gens {gen_lens}, slots={slots}")

    engine = ServeEngine(cfg, params0, num_slots=slots, max_len=max_len)
    engine.warmup(prompt_lens)

    static = engine.run(trace, policy="static")
    ms = _metrics_row("serve_static", static)
    cont = engine.run(trace, policy="continuous")
    mc = _metrics_row("serve_continuous", cont)
    assert ms["generated_tokens"] == mc["generated_tokens"] == budget

    # the tentpole claim, gated here so the CI smoke step tracks it: the
    # continuous engine beats the static baseline on BOTH throughput and
    # tail time-to-first-token under the same backlog
    assert mc["tok_s"] > ms["tok_s"], (mc["tok_s"], ms["tok_s"])
    assert mc["ttft_p99_ms"] < ms["ttft_p99_ms"], (
        mc["ttft_p99_ms"], ms["ttft_p99_ms"])

    # ---- mid-trace hot swap off a live chain -----------------------------
    chain = Chain(k_updates_per_round=1)
    chain.append_model(params0, 0)
    params1 = init_model(jax.random.PRNGKey(7), cfg)
    swap_tick = max(2, cont.ticks // 2)
    committed = []

    def commit(tick):
        if tick == swap_tick and not committed:
            chain.append_update(
                jax.tree.map(np.zeros_like, params0), uploader=0, score=1.0)
            chain.append_model(params1, 1)
            committed.append(tick)

    swap_engine = ServeEngine(
        cfg, params0, num_slots=slots, max_len=max_len,
        param_source=ChainParamSource(chain),
    )
    swap_engine.warmup(prompt_lens)
    swapped = swap_engine.run(trace, policy="continuous", on_tick=commit)
    msw = _metrics_row("serve_continuous_swap", swapped)
    # no request dropped or truncated across the swap
    assert msw["swaps"] == 1, msw
    assert all(len(r.tokens) == r.max_new for r in swapped.results)
    spanned = sum(r.spans_swap for r in swapped.results)
    RESULTS["serve_continuous_swap"]["spanned_swap"] = spanned
    print(f"# hot-swap at tick {swap_tick}: {spanned} in-flight requests "
          f"crossed rounds without dropping")

    # ---- modeled per-token decode cost -----------------------------------
    import jax.numpy as jnp

    from repro.launch.mesh import make_host_mesh
    from repro.launch.shardings import ShardingPolicy
    from repro.launch.steps import make_decode_step

    mesh = make_host_mesh(1, 1)
    pol = ShardingPolicy(dp_axes=("data",), dp_sizes=(1,),
                         model_axis_size=1, fsdp=False)
    step = jax.jit(make_decode_step(cfg, mesh, pol, return_logits=False))
    cache = init_cache(cfg, slots, max_len, jnp.dtype(cfg.dtype))
    hlo = step.lower(
        params0, jnp.zeros((slots, 1), jnp.int32),
        jnp.zeros((slots,), jnp.int32), cache, None,
    ).compile().as_text()
    pt = decode_per_token_stats(hlo, slots)
    emit(
        "serve_decode_hlo", 0.0,
        derived=(f"batch={slots};"
                 f"dot_flops_per_token={pt['dot_flops_per_token']:.0f};"
                 f"collective_bytes_per_token="
                 f"{pt['collective_bytes_per_token']:.0f}"),
        nbytes=int(pt["dot_bytes_per_token"]),
    )
    RESULTS["serve_decode_hlo"].update(
        {k: round(v, 1) for k, v in pt.items()})


if __name__ == "__main__":
    import argparse

    from repro.hostdevices import force_host_devices

    force_host_devices()

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI sanity scale: small trace, short budgets")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default=None,
                    help="also write the emitted rows as JSON (the CI "
                         "smoke step uploads this)")
    args = ap.parse_args()
    run(full=args.full, smoke=args.smoke)
    if args.out:
        import json

        with open(args.out, "w") as f:
            json.dump(RESULTS, f, indent=2)
        print(f"# wrote {args.out}")
