"""Pallas kernel microbenchmarks (interpret mode on CPU: correctness-scale
numbers; the BlockSpec tiling is the TPU deliverable).

The fused-vs-staged rows model HBM traffic analytically (bytes column):
interpret-mode wall-clock is launch-overhead-dominated, so the byte model
is the number that predicts TPU behavior — fused reads the int8 stack once
and writes one tile, staged pays ~3 extra f32 passes over (K, D).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_us
from repro import kernels
from repro.kernels import ops, ref


def _staged_bytes(K: int, dpad: int, nblk: int) -> int:
    """dequant (int8 read, f32 write) -> agg (f32 read, f32 write)
    -> quant (f32 read, int8+scales write)."""
    return (K * dpad + K * nblk * 4          # int8 stack + scales read
            + K * dpad * 4                   # f32 stack write
            + K * dpad * 4                   # f32 stack read
            + dpad * 4                       # f32 result write
            + dpad * 4                       # f32 result read
            + dpad + nblk * 4)               # int8 result + scales write


def _fused_bytes(K: int, dpad: int, nblk: int) -> int:
    """one int8 read of the stack + one int8 write of the result."""
    return (K * dpad + K * nblk * 4          # int8 stack + scales read
            + dpad + nblk * 4)               # int8 result + scales write


def run(full: bool = False):
    sizes = [(8, 1 << 16), (16, 1 << 18)] if full else [(8, 1 << 14)]
    for K, D in sizes:
        stack = jax.random.normal(jax.random.PRNGKey(0), (K, D), jnp.float32)
        w = jnp.full((K,), 1.0 / K)
        x = stack[0]
        dpad = kernels.padded_dim(D)
        nblk = dpad // kernels.BLOCK_D

        us = time_us(lambda: ops.fedavg_agg(stack, w), iters=3)
        us_ref = time_us(lambda: ref.fedavg_agg_ref(stack, w), iters=3)
        emit(f"fedavg_agg_K{K}_D{D}", us, f"ref_us={us_ref:.1f}",
             nbytes=K * dpad * 4 + dpad * 4)

        us = time_us(lambda: ops.cwmed(stack), iters=3)
        us_ref = time_us(lambda: ref.cwmed_ref(stack), iters=3)
        emit(f"cwmed_K{K}_D{D}", us, f"ref_us={us_ref:.1f}",
             nbytes=K * dpad * 4 + dpad * 4)

        us = time_us(lambda: ops.trimmed_mean(stack, trim=1), iters=3)
        us_ref = time_us(lambda: ref.trimmed_mean_ref(stack, 1), iters=3)
        emit(f"trimmed_mean_K{K}_D{D}", us, f"ref_us={us_ref:.1f}",
             nbytes=K * dpad * 4 + dpad * 4)

        # quantize codec: f32 (4*D) -> int8 (dpad) + f32 scale per tile
        us = time_us(lambda: ops.quantize(x), iters=3)
        q_bytes = dpad + 4 * nblk
        emit(f"quantize_D{D}", us,
             f"bytes_saved={(x.nbytes - q_bytes) / x.nbytes:.2f}",
             nbytes=q_bytes)

        # fused one-pass int8 aggregation vs the staged pipeline it replaces
        q, s, d = ops.quantize_stack(stack)

        def staged():
            f32 = jnp.stack([ops.dequantize(q[i], s[i], d) for i in range(K)])
            out = ops.fedavg_agg(f32, w)
            return ops.quantize(out)

        def fused():
            return ops.aggregate_quantized(
                q, s, d, method="fedavg", weights=w, quantize_out=True
            )

        us_staged = time_us(staged, iters=3)
        us_fused = time_us(fused, iters=3)
        sb, fb = _staged_bytes(K, dpad, nblk), _fused_bytes(K, dpad, nblk)
        emit(f"staged_deq_fedavg_quant_K{K}_D{D}", us_staged,
             f"hbm_bytes={sb}", nbytes=sb)
        emit(f"fused_int8_fedavg_K{K}_D{D}", us_fused,
             f"hbm_bytes={fb} vs_staged={us_fused / max(us_staged, 1e-9):.2f}x "
             f"bytes_ratio={fb / sb:.3f}", nbytes=fb)

        for method in ("cwmed", "trimmed_mean"):
            us = time_us(
                lambda m=method: ops.aggregate_quantized(
                    q, s, d, method=m, weights=w, quantize_out=True
                ),
                iters=3,
            )
            emit(f"fused_int8_{method}_K{K}_D{D}", us,
                 f"hbm_bytes={fb}", nbytes=fb)

        # fused candidate rebuild (committee validation, score-from-int8):
        # staged = dequantize rows to a f32 stack, then add the base params
        # (two f32 materializations of (K, D)); fused = one int8 read with
        # the delta applied during the base-parameter load
        base = stack[0]

        def staged_cand():
            f32 = jnp.stack([ops.dequantize(q[i], s[i], d) for i in range(K)])
            return f32 + base[None, :]

        def fused_cand():
            return ops.candidates_from_quantized(base, q, s, d)

        us_staged = time_us(staged_cand, iters=3)
        us_fused = time_us(fused_cand, iters=3)
        sb = (K * dpad + K * nblk * 4      # int8 stack + scales read
              + 2 * K * dpad * 4           # f32 stack write + read back
              + dpad * 4                   # base params read
              + K * dpad * 4)              # candidate stack write
        fb = (K * dpad + K * nblk * 4      # int8 stack + scales read
              + dpad * 4                   # base params read
              + K * dpad * 4)              # candidate stack write (once)
        emit(f"staged_candidates_K{K}_D{D}", us_staged,
             f"hbm_bytes={sb}", nbytes=sb)
        emit(f"fused_candidates_K{K}_D{D}", us_fused,
             f"hbm_bytes={fb} vs_staged={us_fused / max(us_staged, 1e-9):.2f}x "
             f"bytes_ratio={fb / sb:.3f}", nbytes=fb)


if __name__ == "__main__":
    run(full=True)
