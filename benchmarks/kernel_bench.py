"""Pallas kernel microbenchmarks (interpret mode on CPU: correctness-scale
numbers; the BlockSpec tiling is the TPU deliverable)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_us
from repro.kernels import ops, ref


def run(full: bool = False):
    sizes = [(8, 1 << 16), (16, 1 << 18)] if full else [(8, 1 << 14)]
    for K, D in sizes:
        stack = jax.random.normal(jax.random.PRNGKey(0), (K, D), jnp.float32)
        w = jnp.full((K,), 1.0 / K)
        x = stack[0]

        us = time_us(lambda: ops.fedavg_agg(stack, w), iters=3)
        us_ref = time_us(lambda: ref.fedavg_agg_ref(stack, w), iters=3)
        emit(f"fedavg_agg_K{K}_D{D}", us, f"ref_us={us_ref:.1f}")

        us = time_us(lambda: ops.cwmed(stack), iters=3)
        us_ref = time_us(lambda: ref.cwmed_ref(stack), iters=3)
        emit(f"cwmed_K{K}_D{D}", us, f"ref_us={us_ref:.1f}")

        us = time_us(lambda: ops.quantize(x), iters=3)
        emit(f"quantize_D{D}", us,
             f"bytes_saved={(x.nbytes - D - 4*(D//2048))/x.nbytes:.2f}")


if __name__ == "__main__":
    run(full=True)
