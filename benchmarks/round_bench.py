"""Round-loop stage timings: where a BFLC round spends its wall clock.

Runs a small community through the stage pipeline for the f32 (``pytree``)
and fused-int8 engines, plus the sharded multi-device engine at each
available device count, and reports the mean per-stage time from
``RoundContext.timings``.  Compilation is hoisted out of the timed loop:
every runtime first runs ``WARMUP`` throwaway rounds (XLA compilation +
first-shape retraces land there, and ``RoundPipeline._timed`` blocks on
stage outputs, the same warmup-blocking discipline as ``common.time_us``),
then the timing window opens on steady-state rounds only.

``benchmarks.run`` snapshots these rows to ``BENCH_round.json`` so
round-loop perf — including sharded train/aggregate scaling with device
count — is tracked across PRs alongside ``BENCH_kernels.json``.  The
multi-device rows need forced host devices; ``benchmarks.run`` sets
``--xla_force_host_platform_device_count=8`` before jax initializes.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.api import build_runtime
from repro.data import make_femnist_like
from repro.fl import femnist_adapter
from repro.fl.pipeline import STAGE_TIMING_KEYS

WARMUP = 2   # rounds whose timings are dropped (compilation / retraces)


def _steady_timings(rt, rounds: int):
    """Warmed-up per-round stage timings: WARMUP rounds run and are
    discarded before the timed window opens."""
    rt.run(WARMUP, eval_every=WARMUP + 1)
    rt.stage_timings.clear()
    rt.run(rounds, eval_every=rounds + 1)
    return rt.stage_timings


def _emit_variant(name: str, timings) -> None:
    total = 0.0
    for key in STAGE_TIMING_KEYS:
        us = float(np.mean([t[key] for t in timings])) * 1e6
        total += us
        emit(f"round_{name}_{key}", us)
    emit(f"round_{name}_total", total,
         f"rounds={len(timings)};stages={len(STAGE_TIMING_KEYS)}")


def run(full: bool = False):
    import jax

    from repro.launch.mesh import make_round_mesh

    # community sized so p_trainers (= n_active - q_committee) lands on a
    # multiple of 8: the sharded rows then measure scaling, not padding
    # (42 clients -> 21 active, q=5, P=16; 84 -> 42 active, q=10, P=32)
    clients = 84 if full else 42
    rounds = 6 if full else 3
    ds = make_femnist_like(num_clients=clients, mean_samples=60,
                           test_size=400, seed=2)
    adapter = femnist_adapter(width=16 if full else 8)

    base = dict(active_proportion=0.5, committee_fraction=0.25,
                k_updates=6, local_steps=10, local_batch=32, seed=0)
    int8 = dict(base, quantize_chain=True, use_kernels=True)

    print("# round-loop per-stage timings (us, mean over steady-state "
          "rounds; compilation paid in warmup rounds)")
    print("variant_stage,us")
    for variant, cfg in (("f32", base), ("int8", int8)):
        rt = build_runtime(adapter, ds, dict(cfg))
        _emit_variant(variant, _steady_timings(rt, rounds))
        assert rt.chain.verify()

    # sharded engine: train shard_mapped over the data axis, aggregation
    # D-sharded — one row set per device count so BENCH_round.json tracks
    # scaling (on CPU the forced devices share the host's cores: train
    # scales until the core budget is spent, aggregate is bandwidth-bound)
    ndevs = [n for n in (1, 2, 4, 8) if n <= len(jax.devices())]
    if len(ndevs) < 2:
        print("# (single device only: run under "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
              "for the scaling rows)")
    for ndev in ndevs:
        rt = build_runtime(adapter, ds, dict(int8),
                           mesh=make_round_mesh(ndev))
        _emit_variant(f"sharded_dev{ndev}", _steady_timings(rt, rounds))
        assert rt.chain.verify()


if __name__ == "__main__":
    run(full=True)
