"""Round-loop stage timings: where a BFLC round spends its wall clock.

Runs a small community through the stage pipeline for the f32 (``pytree``)
and fused-int8 engines, plus the sharded multi-device engine at each
available device count, and reports the mean per-stage time from
``RoundContext.timings``.  Compilation is hoisted out of the timed loop:
every runtime first runs ``WARMUP`` throwaway rounds (XLA compilation +
first-shape retraces land there, and ``RoundPipeline._timed`` blocks on
stage outputs, the same warmup-blocking discipline as ``common.time_us``),
then the timing window opens on steady-state rounds only.

The ``validate`` and ``aggregate`` rows additionally carry modeled HBM
traffic (bytes column): the update-stack bytes each engine moves per
round — every default validator scores f32 (it reads the (P, D) stack
and writes the (P, D) candidate stack once; the opt-in fused int8-view
scorers are byte-modeled in kernel_bench's ``fused_candidates`` rows),
f32 aggregation reads the (K, D) stack, and fused-int8 aggregation
reads the int8 stack once (PR 1's model).  Interpret-mode wall-clock on
CPU is launch-dominated, so the byte model is the number that predicts
TPU behavior.

``benchmarks.run`` snapshots these rows to ``BENCH_round.json`` so
round-loop perf — including sharded train/validate/aggregate scaling with
device count — is tracked across PRs alongside ``BENCH_kernels.json``.
The multi-device rows need forced host devices; ``benchmarks.run`` sets
``--xla_force_host_platform_device_count=8`` before jax initializes.

Standalone CLI (the CI fast lane's bench smoke step):

  PYTHONPATH=src python -m benchmarks.round_bench --rounds 1 --smoke
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.api import build_runtime
from repro.data import make_femnist_like
from repro.fl import femnist_adapter
from repro.fl.pipeline import STAGE_TIMING_KEYS

WARMUP = 2   # rounds whose timings are dropped (compilation / retraces)


def _steady_timings(rt, rounds: int):
    """Warmed-up per-round stage timings: WARMUP rounds run and are
    discarded before the timed window opens."""
    rt.run(WARMUP, eval_every=WARMUP + 1)
    rt.stage_timings.clear()
    rt.run(rounds, eval_every=rounds + 1)
    return rt.stage_timings


def _stack_dim(rt) -> int:
    """Flattened update dimension D of the runtime's model."""
    from jax.flatten_util import ravel_pytree

    return int(ravel_pytree(rt.global_params())[0].shape[0])


def _stage_bytes(rt, quantized: bool):
    """Modeled update-stack HBM traffic per round for the validate and
    aggregate stages (the bytes column of BENCH_round.json).

    validate — P candidates against Q member batches: read the P-row
    update stack + the base params, write the (P, D) f32 candidate stack
    once (the restructured engine materializes each candidate once per
    update, not once per (i, j) pair; the sharded engine moves the same
    bytes, split across shards).  Every default validator scores f32 —
    the fused int8-view scorers are opt-in and byte-modeled in
    kernel_bench's ``fused_candidates`` rows.

    aggregate — read the K-row packed stack, write the (D,) result:
    f32 reads K*D*4; the fused int8 engine reads the int8 stack + scales
    once (kernel_bench's ``_fused_bytes`` model).
    """
    from repro.kernels.ops import padded_dim
    from repro.kernels.tiling import BLOCK_D

    D = _stack_dim(rt)
    dpad = padded_dim(D)
    nblk = dpad // BLOCK_D
    P = rt.p_trainers
    K = rt.cfg.k_updates
    f32_row, int8_row = D * 4, dpad + nblk * 4
    validate = (P * f32_row          # update stack read
                + f32_row            # base params read
                + P * f32_row)       # candidate stack write (once, fused)
    if quantized:
        aggregate = K * int8_row + f32_row        # int8 stack read + result
    else:
        aggregate = K * f32_row + f32_row         # f32 stack read + result
    return {"validate": validate, "aggregate": aggregate}


def _emit_variant(name: str, timings, stage_bytes=None) -> None:
    stage_bytes = stage_bytes or {}
    total = 0.0
    for key in STAGE_TIMING_KEYS:
        us = float(np.mean([t[key] for t in timings])) * 1e6
        total += us
        emit(f"round_{name}_{key}", us, nbytes=stage_bytes.get(key))
    emit(f"round_{name}_total", total,
         f"rounds={len(timings)};stages={len(STAGE_TIMING_KEYS)}")


def run(full: bool = False, rounds: int | None = None, smoke: bool = False):
    import jax

    from repro.launch.mesh import make_round_mesh

    # community sized so p_trainers (= n_active - q_committee) lands on a
    # multiple of 8: the sharded rows then measure scaling, not padding
    # (42 clients -> 21 active, q=5, P=16; 84 -> 42 active, q=10, P=32).
    # Smoke mode (the CI bench step) shrinks everything to compile+run
    # sanity scale: the rows exist and are ordered, not steady-state.
    if smoke:
        clients, width, steps = 18, 4, 2
        rounds = 1 if rounds is None else rounds
    else:
        clients = 84 if full else 42
        width, steps = (16, 10) if full else (8, 10)
        rounds = (6 if full else 3) if rounds is None else rounds
    ds = make_femnist_like(num_clients=clients, mean_samples=60,
                           test_size=400 if not smoke else 80, seed=2)
    adapter = femnist_adapter(width=width)

    base = dict(active_proportion=0.5, committee_fraction=0.25,
                k_updates=6 if not smoke else 3, local_steps=steps,
                local_batch=32 if not smoke else 8, seed=0)
    int8 = dict(base, quantize_chain=True, use_kernels=True)

    print("# round-loop per-stage timings (us, mean over steady-state "
          "rounds; compilation paid in warmup rounds)")
    print("variant_stage,us")
    for variant, cfg in (("f32", base), ("int8", int8)):
        rt = build_runtime(adapter, ds, dict(cfg))
        timings = _steady_timings(rt, rounds)
        _emit_variant(variant, timings,
                      _stage_bytes(rt, quantized=(variant == "int8")))
        assert rt.chain.verify()

    # sharded engine: train AND committee validation shard_mapped over the
    # data axis, aggregation D-sharded — one row set per device count so
    # BENCH_round.json tracks scaling (on CPU the forced devices share the
    # host's cores: train/validate scale until the core budget is spent,
    # aggregate is bandwidth-bound)
    ndevs = [n for n in (1, 2, 4, 8) if n <= len(jax.devices())]
    if smoke:
        ndevs = ndevs[:2]
    if len(ndevs) < 2:
        print("# (single device only: run under "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
              "for the scaling rows)")
    for ndev in ndevs:
        rt = build_runtime(adapter, ds, dict(int8),
                           mesh=make_round_mesh(ndev))
        timings = _steady_timings(rt, rounds)
        _emit_variant(f"sharded_dev{ndev}", timings,
                      _stage_bytes(rt, quantized=True))
        assert rt.chain.verify()

        # async schedule over the same sharded stage set: cohort t+1's
        # shard_mapped training overlaps cohort t's committee work, so
        # the async total should approach the train bucket alone (the
        # buckets are host-attributed — overlapped device time lands in
        # whichever bucket blocked on it, and their sum stays the wall
        # clock of the round)
        rt = build_runtime(adapter, ds, dict(int8),
                           mesh=make_round_mesh(ndev), schedule="async")
        timings = _steady_timings(rt, rounds)
        _emit_variant(f"async_dev{ndev}", timings,
                      _stage_bytes(rt, quantized=True))
        assert rt.chain.verify()

    # hierarchical rounds under both schedules: the tiered sampler is
    # prefetch_safe, so the async engine pipelines the slices — slice
    # s+1 trains while slice s runs committee consensus + sub-aggregation
    # (smoke's community is too small to tier: 2 slices can't both seat
    # a 3-member sub-committee over its active set)
    if not smoke:
        tiered = dict(int8, active_proportion=1.0, tiers=2)
        ndev = ndevs[-1]
        for label, kw in ((f"tiered_dev{ndev}", {}),
                          (f"tiered_async_dev{ndev}", {"schedule": "async"})):
            rt = build_runtime(adapter, ds, dict(tiered),
                               mesh=make_round_mesh(ndev), **kw)
            timings = _steady_timings(rt, rounds)
            _emit_variant(label, timings, _stage_bytes(rt, quantized=True))
            assert rt.chain.verify()


if __name__ == "__main__":
    import argparse

    # forced host devices for the sharded rows, set before jax touches its
    # backend (module imports above don't query devices)
    from repro.hostdevices import force_host_devices

    force_host_devices()

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale community (slow)")
    ap.add_argument("--rounds", type=int, default=None,
                    help="timed rounds per variant (default: 3, 6 with "
                         "--full, 1 with --smoke)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI sanity scale: tiny community, 2 device counts")
    ap.add_argument("--out", default=None,
                    help="also write the emitted rows as JSON (the CI smoke "
                         "step uploads this so PR artifacts carry measured "
                         "numbers, not just the committed snapshots)")
    args = ap.parse_args()
    run(full=args.full, rounds=args.rounds, smoke=args.smoke)
    if args.out:
        import json

        from benchmarks.common import RESULTS

        with open(args.out, "w") as f:
            json.dump(RESULTS, f, indent=2)
        print(f"# wrote {args.out}")
