"""Round-loop stage timings: where a BFLC round spends its wall clock.

Runs a small community through the stage pipeline for both aggregation
engines (f32 ``pytree`` and fused ``int8``) and reports the mean
per-stage time from ``RoundContext.timings`` (round 0 is dropped — it
pays XLA compilation).  ``benchmarks.run`` snapshots these rows to
``BENCH_round.json`` so round-loop perf is tracked across PRs alongside
``BENCH_kernels.json``.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.api import build_runtime
from repro.data import make_femnist_like
from repro.fl import femnist_adapter
from repro.fl.pipeline import STAGE_TIMING_KEYS


def run(full: bool = False):
    clients = 80 if full else 40
    rounds = 8 if full else 4
    ds = make_femnist_like(num_clients=clients, mean_samples=60,
                           test_size=400, seed=2)
    adapter = femnist_adapter(width=16 if full else 8)

    base = dict(active_proportion=0.4, committee_fraction=0.3,
                k_updates=6, local_steps=10, local_batch=32, seed=0)
    variants = {
        "f32": dict(base),
        "int8": dict(base, quantize_chain=True, use_kernels=True),
    }

    print("# round-loop per-stage timings (us, mean over post-compile rounds)")
    print("variant_stage,us")
    for variant, cfg in variants.items():
        rt = build_runtime(adapter, ds, cfg)
        rt.run(rounds, eval_every=rounds + 1)
        assert rt.chain.verify()
        steady = rt.stage_timings[1:]     # round 0 pays compilation
        total = 0.0
        for key in STAGE_TIMING_KEYS:
            us = float(np.mean([t[key] for t in steady])) * 1e6
            total += us
            emit(f"round_{variant}_{key}", us)
        emit(f"round_{variant}_total", total,
             f"rounds={len(steady)};stages={len(STAGE_TIMING_KEYS)}")


if __name__ == "__main__":
    run(full=True)
