"""Paper Table I: accuracy of BFLC / Basic FL / stand-alone vs active-node
proportion k% on the FEMNIST-like federated dataset.

Scaled to this container by default (fewer clients/rounds than the paper's
900 clients); pass full=True for a closer-to-paper sweep.  The paper's
qualitative claims this reproduces: (1) BFLC ~ Basic FL at every k, (2) both
slightly below stand-alone, (3) accuracy roughly flat in k.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.data import make_femnist_like
from repro.fl import (
    BFLCConfig, BFLCRuntime, FLConfig, FLTrainer, femnist_adapter,
    train_standalone,
)


def run(full: bool = False):
    clients = 150 if full else 60
    rounds = 60 if full else 12
    props = (0.1, 0.2, 0.3, 0.4, 0.5) if full else (0.1, 0.3, 0.5)
    ds = make_femnist_like(
        num_clients=clients, mean_samples=80, test_size=1500 if full else 600,
        seed=1,
    )
    adapter = femnist_adapter(width=16)

    t0 = time.time()
    _, accs = train_standalone(
        adapter, ds, steps=rounds * 20, batch=64, lr=0.05,
        eval_every=max(rounds * 10, 1),
    )
    standalone = accs[-1]

    print("# Table1: accuracy vs active-node proportion")
    print("framework," + ",".join(f"{p:.0%}" for p in props))
    rows = {"BFLC": [], "BasicFL": []}
    for prop in props:
        cfg = BFLCConfig(active_proportion=prop, committee_fraction=0.4,
                         k_updates=max(3, int(clients * prop * 0.4)),
                         local_steps=20, local_batch=32, seed=0)
        rt = BFLCRuntime(adapter, ds, cfg)
        rt.run(rounds, eval_every=rounds)
        rows["BFLC"].append(rt.logs[-1].test_accuracy)
        assert rt.chain.verify()

        fl = FLTrainer(adapter, ds, FLConfig(
            active_proportion=prop, local_steps=20, local_batch=32, seed=0))
        fl.run(rounds, eval_every=rounds)
        rows["BasicFL"].append(fl.accuracies[-1])

    for name, vals in rows.items():
        print(f"{name}," + ",".join(f"{v:.4f}" for v in vals))
    print(f"Stand-alone," + ",".join(f"{standalone:.4f}" for _ in props))
    dt = (time.time() - t0) * 1e6
    gap = np.mean(np.abs(np.array(rows["BFLC"]) - np.array(rows["BasicFL"])))
    emit("table1_accuracy", dt / max(len(props), 1),
         f"standalone={standalone:.3f};mean_bflc_fedavg_gap={gap:.3f}")
    return rows, standalone


if __name__ == "__main__":
    run(full=True)
