"""Paper §V.A consensus-cost claim: CCM = P*Q vs broadcast = (P+Q)^2,
plus a measured microbenchmark of the batched P x Q validation matrix
(the actual compute realization of the cost model).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, time_us
from repro.core.consensus import consensus_cost
from repro.fl.adapter import femnist_adapter
from repro.fl.client import make_score_matrix_fn


def run(full: bool = False):
    from repro.core.pbft import round_messages

    print("# consensus cost model: active nodes split P trainers / Q committee")
    print("active,P,Q,ccm_PQ,broadcast_(P+Q)^2,ratio,"
          "ccm+committee_pbft,network_pbft,pbft_ratio")
    for active in (50, 90, 200, 450, 900):
        q = int(active * 0.4)
        p = active - q
        ccm, bc = consensus_cost(p, q)
        m = round_messages(p, q, k=max(1, p // 2))
        print(f"{active},{p},{q},{ccm},{bc},{bc/ccm:.1f},"
              f"{m.total_ccm},{m.network_pbft},"
              f"{m.network_pbft/max(m.total_ccm,1):.1f}")

    # measured: the vmapped P x Q validation matrix on CPU
    adapter = femnist_adapter(width=8)
    params = adapter.init(jax.random.PRNGKey(0))
    score = make_score_matrix_fn(adapter)
    P, Q, vb = (16, 8, 32) if not full else (54, 36, 64)
    updates = jax.tree.map(
        lambda x: 0.01 * jax.random.normal(
            jax.random.PRNGKey(1), (P,) + x.shape, x.dtype
        ),
        params,
    )
    vx = jax.random.normal(jax.random.PRNGKey(2), (Q, vb, 28, 28, 1))
    vy = jax.random.randint(jax.random.PRNGKey(3), (Q, vb), 0, 62)
    us = time_us(lambda: score(params, updates, vx, vy), iters=3)
    emit("consensus_validation_matrix", us,
         f"P={P};Q={Q};per_validation_us={us/(P*Q):.1f}")


if __name__ == "__main__":
    run(full=True)
