"""Paper Fig. 3: conspiracy-attack success probability over (p, q), A=1000.

Exact hypergeometric computation; asserts the paper's 51% claim.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.security import attack_success_probability, fig3_grid


def run(full: bool = False):
    A = 1000
    ps = np.array([0.05, 0.1, 0.2, 0.3, 0.4, 0.5])
    qs = np.arange(0.05, 1.0, 0.05) if full else np.array(
        [0.1, 0.3, 0.45, 0.5, 0.55, 0.7, 0.9]
    )
    t0 = time.perf_counter()
    grid = fig3_grid(A=A, ps=ps, qs=qs)
    dt = (time.perf_counter() - t0) * 1e6 / (len(ps) * len(qs))

    print("# Fig3: attack success probability, A=1000 (rows p, cols q)")
    header = "p\\q," + ",".join(f"{q:.2f}" for q in qs)
    print(header)
    for i, p in enumerate(ps):
        print(f"{p:.2f}," + ",".join(f"{v:.4f}" for v in grid["prob"][i]))

    # the paper's claim: markedly > 0 only when q > 50%
    below = grid["prob"][:, qs < 0.45]
    assert below.max() < 0.05, below.max()
    print(f"fig3_attack_probability,{dt:.1f},claim_51pct_verified")
    return grid


if __name__ == "__main__":
    run(full=True)
