"""Roofline table: reads the dry-run records in experiments/dryrun/*.json and
prints the three-term analysis per (arch x shape x mesh) for EXPERIMENTS.md
§Roofline."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit
from repro.launch.hlo_stats import HBM_BW, ICI_BW, PEAK_FLOPS

DRYRUN_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "experiments", "dryrun",
)

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,
    "long_500k": 1,
}


def model_flops(rec) -> float:
    """6*N*D (dense) or 6*N_active*D per step; decode = 2*N_active per token."""
    tokens = SHAPE_TOKENS[rec["shape"]]
    n = rec.get("active_params") or rec.get("params") or 0
    if rec["shape"].startswith(("decode", "long")):
        return 2.0 * n * tokens
    return 6.0 * n * tokens


def run(full: bool = False, tag: str = None):
    if tag is None:
        out = []
        for t in ("baseline", "opt"):
            out += run(full=full, tag=t) or []
        return out
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*_{tag}.json")))
    if not files:
        print(f"# no '{tag}' dry-run records under {DRYRUN_DIR}")
        emit(f"roofline_{tag}", 0.0, "no_records")
        return []
    print(f"# [{tag}] roofline terms per (arch, shape, mesh) — s/step/device")
    print("arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
          "model_tflops,hlo_tflops,useful_ratio,peak_mem_GB")
    rows = []
    for f in files:
        rec = json.load(open(f))
        if rec.get("skipped") or rec.get("error"):
            continue
        r = rec["roofline"]
        chips = rec["chips"]
        mf = model_flops(rec) / chips           # per device
        hf = rec["flops_per_device"]
        ratio = mf / hf if hf else 0.0
        pm = (rec.get("peak_memory_per_device") or 0) / 1e9
        rows.append(rec)
        print(
            f"{rec['arch']},{rec['shape']},{rec['mesh']},"
            f"{r['compute_s']:.4f},{r['memory_s']:.4f},"
            f"{r['collective_s']:.4f},{r['dominant']},"
            f"{mf/1e12:.2f},{hf/1e12:.2f},{ratio:.2f},{pm:.2f}"
        )
    emit(f"roofline_{tag}", 0.0, f"records={len(rows)}")
    return rows


if __name__ == "__main__":
    run(full=True)
