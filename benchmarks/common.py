"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

# rows emitted by the current benchmark module: name -> {us, bytes, derived}.
# run.py snapshots this per module to build machine-readable outputs
# (BENCH_kernels.json) that track the perf trajectory across PRs.
RESULTS: Dict[str, Dict] = {}


def _block(out):
    """Wait for async jax work; harmless on non-jax results."""
    try:
        import jax

        return jax.block_until_ready(out)
    except Exception:
        return out


def time_us(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    # block on every warmup result so compilation + warmup compute finish
    # before the timed window opens (async dispatch would otherwise bleed
    # warmup work into — or hide timed work from — the measurement)
    for _ in range(warmup):
        _block(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    _block(out)
    return (time.perf_counter() - t0) / iters * 1e6


def emit(name: str, us_per_call: float, derived: str = "",
         nbytes: Optional[int] = None) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
    RESULTS[name] = {"us": round(us_per_call, 1), "bytes": nbytes,
                     "derived": derived}
