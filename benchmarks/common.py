"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import time
from typing import Callable


def time_us(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    # block on jax results
    try:
        import jax

        jax.block_until_ready(out)
    except Exception:
        pass
    return (time.perf_counter() - t0) / iters * 1e6


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
