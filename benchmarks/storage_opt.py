"""§IV.D storage optimization: chain bytes with/without pruning and with the
int8 update codec (beyond-paper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_us
from repro.core.blockchain import Chain
from repro.kernels.ops import quantize_pytree


def run(full: bool = False):
    D = 1 << 18 if full else 1 << 14
    model = {"w": jnp.zeros((D,), jnp.float32)}
    upd = {"w": 0.01 * jax.random.normal(jax.random.PRNGKey(0), (D,))}
    rounds, k = (10, 8) if full else (4, 4)

    def build(quantized: bool, prune: bool) -> int:
        chain = Chain(k)
        chain.append_model(model, 0)
        for t in range(rounds):
            for i in range(k):
                payload = quantize_pytree(upd)[0] if quantized else upd
                chain.append_update(payload, i, 0.9)
            chain.append_model(model, t + 1)
            if prune:
                chain.prune(keep_rounds=1)
        return chain.storage_bytes()

    base = build(False, False)
    pruned = build(False, True)
    quant = build(True, False)
    both = build(True, True)
    print("# chain storage bytes (rounds={}, k={}, D={})".format(rounds, k, D))
    print(f"full,{base}")
    print(f"pruned,{pruned} ({base/pruned:.1f}x)")
    print(f"quantized,{quant} ({base/quant:.1f}x)")
    print(f"pruned+quantized,{both} ({base/both:.1f}x)")
    emit("storage_opt", 0.0,
         f"prune_x={base/pruned:.1f};quant_x={base/quant:.1f};"
         f"both_x={base/both:.1f}")


if __name__ == "__main__":
    run(full=True)
